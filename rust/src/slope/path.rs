//! The regularization-path driver (§2.2.4, §3.1.2): fits
//! `β̂(σ⁽¹⁾), …, β̂(σ⁽ˡ⁾)` with one of five strategies —
//! no screening, the **strong set** algorithm (Algorithm 3), the
//! **previous set** algorithm (Algorithm 4) — both safeguarded by
//! full-gradient KKT checks — or the duality-gap-driven pair:
//! **safe-only** (certified sphere-test universe, no heuristic) and the
//! **gap hybrid** (strong working set + safe universe + gap
//! certificates, DESIGN.md §10), with the paper's three
//! early-termination rules.
//!
//! The full-design gradient `Xᵀh` needed by the rule and the KKT checks is
//! abstracted behind [`FullGradient`], so it can be served either natively
//! (pure Rust) or by the AOT-compiled JAX/Pallas artifact through the PJRT
//! runtime (`crate::runtime`).

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Instant;

use crate::fault;
use crate::jsonio::Json;
use crate::slope::checkpoint::{self, CheckpointError, GapSnap, Snapshot, StepRec};
use crate::linalg::ops::sq_norm;
use crate::linalg::packed::PackCache;
use crate::linalg::ParConfig;
use crate::obs::registry as obsreg;
use crate::slope::cancel::CancelToken;
use crate::slope::family::{Family, Problem};
use crate::slope::fista::{solve, FistaConfig, Reduced};
use crate::slope::lambda::{sigma_grid, sigma_max, PathConfig};
use crate::slope::safe::SafeScreener;
use crate::slope::screen::{gap_safe_set, StrongWorkspace};
use crate::slope::sorted::{support, unique_nonzero_magnitudes};

/// Screening strategy along the path.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Strategy {
    /// Fit every predictor at every step (baseline in Figs. 4–5, Tabs. 1, 3).
    NoScreening,
    /// Algorithm 3: `E = S(λ⁽ᵐ⁺¹⁾) ∪ T(λ⁽ᵐ⁾)`, KKT-check the full set.
    StrongSet,
    /// Algorithm 4: `E = T(λ⁽ᵐ⁾)`, KKT-check the strong set first, then
    /// the full set.
    PreviousSet,
    /// Certified screening only: `E` is the whole sphere-test survivor
    /// universe (every predictor not *provably* zero at this σ — see
    /// [`crate::slope::safe`]), solved to a duality-gap certificate. No
    /// heuristic, hence no violations by construction; far more
    /// conservative than the strong rule (the Fig. 1 comparison).
    SafeOnly,
    /// Celer-style hybrid (DESIGN.md §10): solve on the strong set to an
    /// inner gap, certify with a global duality gap computed over the
    /// sphere-test-shrunken safe universe, and expand by the top-K
    /// ranked violators when the certificate fails — most σ-steps pay a
    /// partial-universe gradient sweep instead of a full one.
    GapHybrid,
}

impl Strategy {
    /// Display name used in result tables.
    pub fn name(&self) -> &'static str {
        match self {
            Strategy::NoScreening => "none",
            Strategy::StrongSet => "strong",
            Strategy::PreviousSet => "previous",
            Strategy::SafeOnly => "safe",
            Strategy::GapHybrid => "hybrid",
        }
    }

    /// True for the strategies driven by the duality-gap certificate
    /// (universe sweeps + gap stopping) instead of the full-p KKT sweep.
    pub fn is_gap_driven(&self) -> bool {
        matches!(self, Strategy::SafeOnly | Strategy::GapHybrid)
    }
}

/// Provider of the full-design gradient `grad = Xᵀ h` (class-blocked for
/// multinomial). This is the O(np) operation the screening rule pays per
/// path step; implementations: native Rust ([`NativeGradient`]) or the
/// PJRT-loaded JAX/Pallas artifact (`runtime::ArtifactGradient`).
pub trait FullGradient {
    /// Full-design gradient at `beta`. Implementations may use either the
    /// coefficient vector (`beta`, flattened class-major — the XLA
    /// artifact recomputes `η` on-device) or the already-computed working
    /// residual (`h`, class-blocked — the native path reuses it and only
    /// pays the `Xᵀh` product).
    fn full_grad(&self, beta: &[f64], h: &[f64], grad: &mut [f64]);

    /// [`FullGradient::full_grad`] with a kernel thread budget. The
    /// default ignores the budget (engines that run off-CPU, like the
    /// PJRT artifact, schedule for themselves); the native engine routes
    /// it into the parallel `Xᵀh` kernel.
    fn full_grad_with(&self, beta: &[f64], h: &[f64], grad: &mut [f64], _par: ParConfig) {
        self.full_grad(beta, h, grad);
    }

    /// Implementation label for logs/EXPERIMENTS.md.
    fn label(&self) -> &'static str;
}

/// Pure-Rust gradient evaluator over the problem's own design matrix.
pub struct NativeGradient<'a>(pub &'a Problem);

impl FullGradient for NativeGradient<'_> {
    fn full_grad(&self, _beta: &[f64], h: &[f64], grad: &mut [f64]) {
        self.0.gradient_from_h(h, grad);
    }

    fn full_grad_with(&self, _beta: &[f64], h: &[f64], grad: &mut [f64], par: ParConfig) {
        self.0.gradient_from_h_with(h, grad, par);
    }

    fn label(&self) -> &'static str {
        "native"
    }
}

/// Options controlling a path fit.
#[derive(Clone, Debug)]
pub struct PathOptions {
    /// Penalty shape, path length, termination rules.
    pub config: PathConfig,
    /// Screening strategy.
    pub strategy: Strategy,
    /// Inner solver configuration.
    pub fista: FistaConfig,
    /// Tolerance for KKT violation detection, relative to `σλ₁`.
    pub kkt_tol: f64,
    /// Also record the gap-safe screened-set size (Gaussian family only;
    /// used by the Figure 1 bench).
    pub record_safe: bool,
    /// Kernel thread budget for the hot linalg (full-gradient sweeps,
    /// `η` products). 0 defers to the process-wide setting
    /// (`linalg::par::set_global_threads`, the CLI `--threads` flag, or
    /// the machine default); 1 forces the serial backend. Callers that
    /// already run fits on a worker pool (serve, CV) pass their per-job
    /// budget here so the two layers of parallelism don't multiply.
    pub threads: usize,
    /// Run reduced solves on the packed engine (screened columns
    /// materialized into a contiguous slab once per step — DESIGN.md §5)
    /// instead of per-iteration gather kernels. On dense designs the two
    /// engines produce bitwise-identical fits; this is a performance
    /// switch, kept so the gather path stays exercised (`path_speed --
    /// --no-pack`). Designs too sparse to repay densification are kept
    /// on the gather kernels regardless (the `packing_profitable`
    /// density gate).
    pub packing: bool,
    /// Shared store of finished packs keyed by screened set. When set,
    /// each step consults it before packing and deposits its final pack
    /// after the safeguard loop — warm-start fits with stable supports
    /// (the serve registry's case) skip packing entirely.
    pub pack_cache: Option<Arc<PackCache>>,
    /// Relative duality-gap tolerance for the gap-driven strategies
    /// ([`Strategy::GapHybrid`], [`Strategy::SafeOnly`]): a step is
    /// accepted once `gap ≤ gap_tol · max(1, |primal|)`. Ignored by the
    /// KKT-safeguarded strategies. Tight by default so gap-certified
    /// fits are interchangeable with strong-rule fits to well below any
    /// reported tolerance.
    pub gap_tol: f64,
    /// Precomputed column norms `‖x_j‖` for the gap-driven strategies'
    /// sphere tests. `None` (the default) computes them per fit — fine
    /// for paths, where one O(n·p) pass amortizes over the whole grid,
    /// but a per-request [`fit_point`] stream should share them (the
    /// serve registry caches one copy per dataset). Must belong to this
    /// problem's design; a wrong-length vector is ignored.
    pub col_norms: Option<Arc<Vec<f64>>>,
    /// Cooperative cancellation: polled at the top of every σ-step and
    /// propagated into every inner FISTA solve, so a fired token (an
    /// expired serve deadline, a client disconnect) stops the fit within
    /// one solver iteration. A cancelled fit returns normally with
    /// [`PathFit::stopped_early`] = `Some("cancelled")` and whatever
    /// steps completed — partial progress, never torn state.
    pub cancel: Option<CancelToken>,
    /// Degradation ladder (DESIGN.md §12): when a step's solve fails its
    /// certificate, retry it under the next-most-conservative strategy
    /// (hybrid/previous → strong → full) before reporting
    /// non-convergence. On by default; tests that *study* loose solves
    /// turn it off.
    pub degrade: bool,
}

impl PathOptions {
    /// Defaults: strong-set algorithm, paper path config, packed engine.
    pub fn new(config: PathConfig) -> Self {
        Self {
            config,
            strategy: Strategy::StrongSet,
            fista: FistaConfig::default(),
            kkt_tol: 1e-5,
            record_safe: false,
            threads: 0,
            packing: true,
            pack_cache: None,
            gap_tol: 1e-10,
            col_norms: None,
            cancel: None,
            degrade: true,
        }
    }

    /// Builder: attach a cooperative cancellation token (see
    /// [`PathOptions::cancel`]).
    pub fn with_cancel(mut self, cancel: CancelToken) -> Self {
        self.cancel = Some(cancel);
        self
    }

    /// Has this fit's token (on the options or the inner solver config)
    /// fired?
    pub fn is_cancelled(&self) -> bool {
        self.cancel.as_ref().map_or(false, |t| t.is_cancelled())
            || self.fista.cancel.as_ref().map_or(false, |t| t.is_cancelled())
    }

    /// Builder: set strategy.
    pub fn with_strategy(mut self, s: Strategy) -> Self {
        self.strategy = s;
        self
    }

    /// Builder: set the relative duality-gap tolerance (see
    /// [`PathOptions::gap_tol`]).
    pub fn with_gap_tol(mut self, gap_tol: f64) -> Self {
        assert!(gap_tol > 0.0, "gap_tol must be positive");
        self.gap_tol = gap_tol;
        self
    }

    /// Builder: share precomputed design column norms with the
    /// gap-driven strategies (see [`PathOptions::col_norms`]).
    pub fn with_col_norms(mut self, col_norms: Arc<Vec<f64>>) -> Self {
        self.col_norms = Some(col_norms);
        self
    }

    /// Builder: set the kernel thread budget (see [`PathOptions::threads`]).
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads;
        self
    }

    /// Builder: enable/disable the packed reduced-design engine.
    pub fn with_packing(mut self, packing: bool) -> Self {
        self.packing = packing;
        self
    }

    /// Builder: attach a shared pack cache. Consulted only while the
    /// packed engine is enabled (`packing`, the default) — turning
    /// packing off leaves an attached cache unused. The cache must
    /// belong to this problem's design: its key is the screened set
    /// alone (see the [`PackCache`] contract).
    pub fn with_pack_cache(mut self, cache: Arc<PackCache>) -> Self {
        self.pack_cache = Some(cache);
        self
    }

    /// The [`ParConfig`] this fit's kernels run under.
    pub fn par(&self) -> ParConfig {
        ParConfig::with_threads(self.threads)
    }
}

/// Per-step diagnostics.
#[derive(Clone, Debug)]
pub struct StepInfo {
    /// Penalty scale σ at this step.
    pub sigma: f64,
    /// Active coefficients at the solution.
    pub n_active: usize,
    /// Size of the raw strong-rule screened set `S(λ⁽ᵐ⁺¹⁾)`.
    pub n_screened_rule: usize,
    /// Final fitted set size (after unions and violation refits).
    pub n_fitted: usize,
    /// Gap-safe screened-set size (if recorded).
    pub n_safe: Option<usize>,
    /// KKT violations encountered (predictors added after a failed check).
    pub violations: usize,
    /// Number of solve/refit rounds (1 = no violations).
    pub refits: usize,
    /// Total inner FISTA iterations.
    pub solver_iterations: usize,
    /// Model deviance.
    pub deviance: f64,
    /// Fraction of null deviance explained.
    pub dev_ratio: f64,
    /// Seconds spent in screening.
    pub t_screen: f64,
    /// Seconds spent in the reduced solver.
    pub t_solve: f64,
    /// Seconds spent in full-gradient + KKT checks.
    pub t_kkt: f64,
    /// Whether every inner solve of this step met its certificate before
    /// `max_iter`. A `false` here means the step's violation count may
    /// include solver noise — surfaced so a non-converged inner solve
    /// can never masquerade as a screening-rule violation.
    pub solver_converged: bool,
    /// Full-design-equivalent gradient sweeps this step paid: each
    /// safeguard round's full `Xᵀh` counts 1.0; the gap-driven
    /// strategies' universe sweeps count `|U| / p` (step 0 records the
    /// β = 0 bootstrap sweep).
    pub full_grad_sweeps: f64,
    /// Safe-universe size at the end of the step (gap-driven strategies
    /// only).
    pub n_universe: Option<usize>,
    /// Certified duality gap at the accepted solution (gap-driven
    /// strategies only).
    pub gap: Option<f64>,
    /// When the step's first solve failed its certificate and the
    /// degradation ladder rescued it, the name of the (more
    /// conservative) strategy that produced the accepted solution —
    /// `None` on the healthy path. See DESIGN.md §12.
    pub degraded_to: Option<&'static str>,
}

/// Result of a full path fit.
#[derive(Clone, Debug)]
pub struct PathFit {
    /// The σ grid actually visited (may be shorter than requested due to
    /// early termination).
    pub sigmas: Vec<f64>,
    /// The base λ sequence (unscaled).
    pub lambda_base: Vec<f64>,
    /// Per-step diagnostics (parallel to `sigmas`).
    pub steps: Vec<StepInfo>,
    /// Sparse solutions per step: `(coef index, value)` pairs.
    pub betas: Vec<Vec<(usize, f64)>>,
    /// Dense final solution.
    pub final_beta: Vec<f64>,
    /// Total violations across the path.
    pub total_violations: usize,
    /// Which early-stop rule fired, if any.
    pub stopped_early: Option<&'static str>,
    /// Total wall time in seconds.
    pub wall_time: f64,
    /// Full-design gradient at the final solution (parallel to
    /// `final_beta`); the warm-start state [`PathFit::seed`] hands to the
    /// next fit. Exact for every strategy — gap-driven fits refresh it
    /// with one closing full sweep when the last step swept only a
    /// partial universe.
    pub final_grad: Vec<f64>,
    /// Total full-design-equivalent gradient sweeps across the fit
    /// (Σ [`StepInfo::full_grad_sweeps`], plus the closing refresh when
    /// a gap-driven fit needed one) — the quantity the `path_speed`
    /// screening-policy gate compares.
    pub total_grad_sweeps: f64,
}

impl PathFit {
    /// Dense solution at step `m`.
    pub fn beta_at(&self, m: usize, p_total: usize) -> Vec<f64> {
        let mut out = vec![0.0; p_total];
        for &(i, v) in &self.betas[m] {
            out[i] = v;
        }
        out
    }

    /// Warm-start state at the final path point, for seeding a later
    /// [`fit_path_seeded`] or [`fit_point`] on the same problem.
    pub fn seed(&self) -> PathSeed {
        PathSeed {
            sigma: self.sigmas.last().copied().unwrap_or(0.0),
            beta: self.final_beta.clone(),
            grad: self.final_grad.clone(),
        }
    }
}

/// Warm-start state at one path point: a solution `β̂(σ)`, the full-design
/// gradient at that solution, and the σ it was solved at. This is exactly
/// what the screening rule needs about the previous point (§2.2.2), so a
/// cache of `PathSeed`s lets screening pay off *across requests*, not just
/// across path steps — the serve layer's warm-start cache stores these.
#[derive(Clone, Debug)]
pub struct PathSeed {
    /// Penalty scale the state was solved at.
    pub sigma: f64,
    /// Dense solution (length `p_total`).
    pub beta: Vec<f64>,
    /// Full gradient `∇f(β)` at `beta` (length `p_total`).
    pub grad: Vec<f64>,
}

/// Result of a single-σ safeguarded fit ([`fit_point`]).
#[derive(Clone, Debug)]
pub struct PointFit {
    /// Penalty scale solved at.
    pub sigma: f64,
    /// Dense solution.
    pub beta: Vec<f64>,
    /// Full gradient at the solution.
    pub grad: Vec<f64>,
    /// Size of the raw screened set proposed by the rule.
    pub n_screened_rule: usize,
    /// Final fitted set size (after unions and violation refits).
    pub n_fitted: usize,
    /// Active coefficients at the solution.
    pub n_active: usize,
    /// Strong-rule violations (see [`StepInfo::violations`]).
    pub violations: usize,
    /// Solve/refit rounds.
    pub refits: usize,
    /// Total inner FISTA iterations.
    pub solver_iterations: usize,
    /// Model deviance.
    pub deviance: f64,
    /// Fraction of null deviance explained.
    pub dev_ratio: f64,
    /// Wall time in seconds.
    pub wall_time: f64,
    /// Whether every inner solve met its certificate (see
    /// [`StepInfo::solver_converged`]).
    pub solver_converged: bool,
    /// Full-design-equivalent gradient sweeps paid (see
    /// [`StepInfo::full_grad_sweeps`]).
    pub full_grad_sweeps: f64,
    /// Certified duality gap at the solution (gap-driven strategies only).
    pub gap: Option<f64>,
    /// Strategy name the degradation ladder rescued this fit under, when
    /// the requested strategy failed to converge (see
    /// [`StepInfo::degraded_to`]).
    pub degraded_to: Option<&'static str>,
}

impl PointFit {
    /// Warm-start state at this point, for the next [`fit_point`].
    pub fn seed(&self) -> PathSeed {
        PathSeed { sigma: self.sigma, beta: self.beta.clone(), grad: self.grad.clone() }
    }
}

/// Fit a full SLOPE regularization path from a cold start.
pub fn fit_path(prob: &Problem, opts: &PathOptions, evaluator: &dyn FullGradient) -> PathFit {
    fit_path_seeded(prob, opts, evaluator, None)
}

/// Loss, working residual and full gradient at `β = 0` — the shared
/// bootstrap of [`zero_seed`] and the path driver. `eta` must be
/// all-zero on entry; `h` and `grad` are filled.
fn state_at_zero(
    prob: &Problem,
    evaluator: &dyn FullGradient,
    eta: &[f64],
    h: &mut [f64],
    grad: &mut [f64],
    par: ParConfig,
) -> f64 {
    let loss0 = prob.family.h_loss(eta, &prob.y, h);
    let zero_beta = vec![0.0; grad.len()];
    evaluator.full_grad_with(&zero_beta, h, grad, par);
    note_full_sweep(grad.len());
    loss0
}

/// Count one full p-column gradient sweep in the registry.
#[inline]
fn note_full_sweep(pt: usize) {
    obsreg::GRAD_FULL_SWEEPS.inc();
    obsreg::GRAD_SWEEP_COLS.add(pt as u64);
}

/// The exact path state at `β = 0`: the full gradient at zero and
/// `σ_max = σ(1)`. This is both the cold-start seed for [`fit_point`] and
/// how a caller resolves relative-σ requests (`σ = ratio · σ_max`).
pub fn zero_seed(prob: &Problem, opts: &PathOptions, evaluator: &dyn FullGradient) -> PathSeed {
    let n = prob.n();
    let m_classes = prob.family.n_classes();
    let pt = prob.p_total();
    let lambda_base = opts.config.kind.sequence(pt);
    let eta = vec![0.0; n * m_classes];
    let mut h = vec![0.0; n * m_classes];
    let mut grad = vec![0.0; pt];
    state_at_zero(prob, evaluator, &eta, &mut h, &mut grad, opts.par());
    let smax = sigma_max(&grad, &lambda_base);
    PathSeed { sigma: smax, beta: vec![0.0; pt], grad }
}

/// Solve the SLOPE problem at a single σ, screened and safeguarded
/// exactly like one step of [`fit_path`], warm-started from `seed` (the
/// state at a previously solved point — use [`zero_seed`] when cold).
///
/// The KKT safeguard makes this correct for *any* seed: the screening
/// heuristic only affects how much work the refit loop does. Feeding the
/// returned [`PointFit::seed`] back in on the next request is what turns
/// per-path-step screening into per-request screening.
pub fn fit_point(
    prob: &Problem,
    opts: &PathOptions,
    evaluator: &dyn FullGradient,
    sigma: f64,
    seed: &PathSeed,
) -> PointFit {
    assert!(sigma > 0.0, "sigma must be positive");
    let t_start = Instant::now();
    let n = prob.n();
    let m_classes = prob.family.n_classes();
    let pt = prob.p_total();
    let lambda_base = opts.config.kind.sequence(pt);
    assert_eq!(seed.beta.len(), pt, "seed beta dimension mismatch");
    assert_eq!(seed.grad.len(), pt, "seed gradient dimension mismatch");

    let dev_null = prob.family.null_deviance(&prob.y);
    let mut beta_full = seed.beta.clone();
    let mut grad = seed.grad.clone();
    let mut eta = vec![0.0; n * m_classes];
    let mut h = vec![0.0; n * m_classes];

    let mut lam_prev = vec![0.0; pt];
    let mut lam_cur = vec![0.0; pt];
    for i in 0..pt {
        lam_prev[i] = lambda_base[i] * seed.sigma;
        lam_cur[i] = lambda_base[i] * sigma;
    }
    let mut screen_ws = StrongWorkspace::default();
    let prev_support = support(&beta_full);
    let (mut out, rule_set, n_screened_rule) = if opts.strategy.is_gap_driven() {
        // Establish the dual state at the seed: η/h/loss at `seed.beta`,
        // with `seed.grad` as the (exact) sphere reference. For warm
        // seeds this is what turns per-step safe screening into
        // per-request safe screening.
        prob.eta_with(&beta_full, &mut eta, opts.par());
        let seed_loss = prob.family.h_loss(&eta, &prob.y, &mut h);
        let mut gs = GapState::new(prob, opts, &h, &grad, seed_loss);
        let sc = gap_screening(
            prob,
            opts,
            &mut gs,
            &lam_prev,
            &lam_cur,
            &prev_support,
            &beta_full,
            &h,
            &mut screen_ws,
        );
        let rule_set = sc.rule_set;
        let n_screened_rule = rule_set.len();
        let mut out = solve_with_gap(
            prob,
            opts,
            evaluator,
            &lambda_base,
            sigma,
            &lam_cur,
            sc.e_set,
            sc.universe,
            sc.gap_abs,
            &mut gs,
            &mut beta_full,
            &mut eta,
            &mut h,
            &mut grad,
            &mut screen_ws,
        );
        // The returned seed's gradient must be exact over every
        // coefficient (the next request's screening reference).
        if !gs.grad_is_exact {
            evaluator.full_grad_with(&beta_full, &h, &mut grad, opts.par());
            note_full_sweep(pt);
            out.sweeps += 1.0;
        }
        (out, rule_set, n_screened_rule)
    } else {
        let (rule_set, n_screened_rule, e_set) = screening_sets(
            opts.strategy,
            pt,
            &grad,
            &lam_prev,
            &lam_cur,
            &prev_support,
            &mut screen_ws,
        );
        let out = solve_with_safeguard(
            prob,
            opts,
            evaluator,
            &lambda_base,
            sigma,
            &lam_cur,
            &rule_set,
            &prev_support,
            e_set,
            &mut beta_full,
            &mut eta,
            &mut h,
            &mut grad,
            &mut screen_ws,
        );
        (out, rule_set, n_screened_rule)
    };

    // Degradation ladder, mirroring the path driver's: a non-converged
    // single-σ fit is retried from the (immutable) seed under the
    // next-most-conservative strategy before being reported. Cancelled
    // fits are never rescued.
    let mut degraded_to: Option<&'static str> = None;
    let mut rung = opts.strategy;
    while opts.degrade && !out.converged && !opts.is_cancelled() {
        let next = match ladder_next(rung) {
            Some(s) => s,
            None => break,
        };
        rung = next;
        beta_full.copy_from_slice(&seed.beta);
        grad.copy_from_slice(&seed.grad);
        // Re-rank on the restored gradient — the workspace still holds
        // the failed attempt's ordering.
        screen_ws.rank(&grad);
        let rescue_opts = PathOptions { strategy: next, ..opts.clone() };
        let (r_rule, _r_n, r_e) = screening_sets(
            next,
            pt,
            &grad,
            &lam_prev,
            &lam_cur,
            &prev_support,
            &mut screen_ws,
        );
        let mut rescue = solve_with_safeguard(
            prob,
            &rescue_opts,
            evaluator,
            &lambda_base,
            sigma,
            &lam_cur,
            &r_rule,
            &prev_support,
            r_e,
            &mut beta_full,
            &mut eta,
            &mut h,
            &mut grad,
            &mut screen_ws,
        );
        obsreg::PATH_DEGRADED_STEPS.inc();
        degraded_to = Some(next.name());
        rescue.solver_iterations += out.solver_iterations;
        rescue.refits += out.refits;
        rescue.sweeps += out.sweeps;
        rescue.t_solve += out.t_solve;
        rescue.t_kkt += out.t_kkt;
        out = rescue;
    }

    let rule_cover = union_sorted(&rule_set, &prev_support);
    let violations = diff_sorted(&out.added_by_kkt, &rule_cover)
        .iter()
        .filter(|&&c| beta_full[c] != 0.0)
        .count();
    let dev = prob.family.deviance(out.loss, &prob.y);
    let dev_ratio = if dev_null > 0.0 { 1.0 - dev / dev_null } else { 0.0 };
    let n_active = support(&beta_full).len();
    PointFit {
        sigma,
        beta: beta_full,
        grad,
        n_screened_rule,
        n_fitted: out.e_set.len(),
        n_active,
        violations,
        refits: out.refits,
        solver_iterations: out.solver_iterations,
        deviance: dev,
        dev_ratio,
        wall_time: t_start.elapsed().as_secs_f64(),
        solver_converged: out.converged,
        full_grad_sweeps: out.sweeps,
        gap: out.gap,
        degraded_to,
    }
}

/// Solve a coalesced batch of single-σ requests over one problem: one
/// [`fit_point`] per entry of `sigmas`, executed **in order** inside the
/// caller's single job. This is the serve layer's cross-request batching
/// entry (DESIGN.md §14): because the items run sequentially, the batch
/// is by construction bitwise identical to the serialization in which
/// its members arrived back-to-back — coalescing changes scheduling,
/// never arithmetic.
///
/// `chain` replicates the registry's warm-start store/read cycle: when
/// set (the cache-enabled server), item `k+1` is seeded from item `k`'s
/// returned state — exactly what sequential handling would have read
/// back from the point cache — except after an item the deadline
/// cancelled mid-solve, whose state sequential handling never stores
/// (the previous usable seed carries forward instead). With `chain`
/// false (cache-disabled server), every item starts from the shared
/// `seed`, matching a sequence of independent cold requests.
///
/// `opts_first` carries the strategy chosen from the *pre-batch* warm
/// state; `opts_rest` the warm follow-up strategy items `1..` would have
/// been handled under once item 0's state was stored. With `chain` off,
/// `opts_first` applies to every item.
pub fn fit_point_batch(
    prob: &Problem,
    opts_first: &PathOptions,
    opts_rest: &PathOptions,
    evaluator: &dyn FullGradient,
    seed: &PathSeed,
    sigmas: &[f64],
    chain: bool,
) -> Vec<PointFit> {
    let mut out = Vec::with_capacity(sigmas.len());
    let mut cur = seed.clone();
    for (k, &sigma) in sigmas.iter().enumerate() {
        let opts = if chain && k > 0 { opts_rest } else { opts_first };
        let fit = fit_point(prob, opts, evaluator, sigma, if chain { &cur } else { seed });
        // A cancelled, non-converged item is the one whose state the
        // sequential server refuses to cache — don't chain from it.
        if chain && !(opts.is_cancelled() && !fit.solver_converged) {
            cur = fit.seed();
        }
        out.push(fit);
    }
    out
}

/// Fit a full SLOPE regularization path, optionally warm-started from the
/// state of a prior fit on the same problem (`seed.beta` primes the first
/// reduced solves; the σ grid itself is recomputed from the gradient at
/// zero, so a seeded fit visits the same grid as a cold one and returns
/// the same solutions — only faster).
pub fn fit_path_seeded(
    prob: &Problem,
    opts: &PathOptions,
    evaluator: &dyn FullGradient,
    seed: Option<&PathSeed>,
) -> PathFit {
    fit_path_driver(prob, opts, evaluator, seed, None, None)
        .expect("a fit without a resume snapshot is infallible")
}

/// Durable-state configuration for a checkpointed fit (DESIGN.md §13).
#[derive(Clone, Debug)]
pub struct CheckpointConfig {
    /// Snapshot file; `<path>.prev` holds the rotated previous snapshot
    /// and `<path>.tmp` is the atomic-write staging name.
    pub path: PathBuf,
    /// Snapshot cadence in σ-steps (degradation/rescue events always
    /// snapshot regardless). Clamped to ≥ 1 by the driver.
    pub every: usize,
    /// Content fingerprint of the dataset this fit runs on (from ingest,
    /// or the canonical synthetic-spec fingerprint). Stamped into every
    /// snapshot and validated on resume.
    pub dataset_fingerprint: u64,
}

/// [`fit_path_seeded`] plus crash safety: the identical fit, with an
/// atomic on-disk [`Snapshot`] every `cfg.every` σ-steps and at every
/// degradation event. Snapshots never touch fit state — the bench
/// `resilience.checkpoint_overhead` cell holds checkpointed ≡ plain
/// bitwise.
pub fn fit_path_checkpointed(
    prob: &Problem,
    opts: &PathOptions,
    evaluator: &dyn FullGradient,
    seed: Option<&PathSeed>,
    cfg: &CheckpointConfig,
) -> PathFit {
    fit_path_driver(prob, opts, evaluator, seed, Some(cfg), None)
        .expect("a fit without a resume snapshot is infallible")
}

/// Resume a checkpointed fit from its last good snapshot (falling back to
/// `<path>.prev` when the primary is corrupt or torn). Validates the full
/// fingerprint chain — dataset, problem, grid, strategy, shapes — then
/// re-enters the σ-loop at the snapshot's `next_step` and continues
/// **bitwise identically** to an uninterrupted fit. Returns the completed
/// fit and the σ index it resumed at. Keeps checkpointing under `cfg` as
/// it goes, so a resumed fit can itself be killed and resumed.
pub fn resume_path(
    prob: &Problem,
    opts: &PathOptions,
    evaluator: &dyn FullGradient,
    cfg: &CheckpointConfig,
) -> Result<(PathFit, usize), CheckpointError> {
    let (snap, _from_prev) = checkpoint::load_with_fallback(&cfg.path)?;
    if snap.dataset_fp != cfg.dataset_fingerprint {
        return Err(CheckpointError::DatasetMismatch {
            expected: cfg.dataset_fingerprint,
            found: snap.dataset_fp,
        });
    }
    let start = snap.next_step as usize;
    let fit = fit_path_driver(prob, opts, evaluator, None, Some(cfg), Some(snap))?;
    Ok((fit, start))
}

/// [`StepInfo`] → its serializable mirror.
fn step_to_rec(s: &StepInfo) -> StepRec {
    StepRec {
        sigma: s.sigma,
        n_active: s.n_active as u64,
        n_screened_rule: s.n_screened_rule as u64,
        n_fitted: s.n_fitted as u64,
        n_safe: s.n_safe.map(|v| v as u64),
        violations: s.violations as u64,
        refits: s.refits as u64,
        solver_iterations: s.solver_iterations as u64,
        deviance: s.deviance,
        dev_ratio: s.dev_ratio,
        t_screen: s.t_screen,
        t_solve: s.t_solve,
        t_kkt: s.t_kkt,
        solver_converged: s.solver_converged,
        full_grad_sweeps: s.full_grad_sweeps,
        n_universe: s.n_universe.map(|v| v as u64),
        gap: s.gap,
        degraded_to: s.degraded_to.map(str::to_string),
    }
}

/// Serialized mirror → [`StepInfo`], mapping the degradation strategy
/// name back to its interned `&'static str` (an unknown name is a typed
/// incompatibility, never a panic).
fn rec_to_step(r: &StepRec) -> Result<StepInfo, CheckpointError> {
    let degraded_to = match r.degraded_to.as_deref() {
        None => None,
        Some(name) => Some(strategy_static_name(name).ok_or_else(|| {
            CheckpointError::Incompatible(format!("unknown degraded_to strategy `{name}`"))
        })?),
    };
    Ok(StepInfo {
        sigma: r.sigma,
        n_active: r.n_active as usize,
        n_screened_rule: r.n_screened_rule as usize,
        n_fitted: r.n_fitted as usize,
        n_safe: r.n_safe.map(|v| v as usize),
        violations: r.violations as usize,
        refits: r.refits as usize,
        solver_iterations: r.solver_iterations as usize,
        deviance: r.deviance,
        dev_ratio: r.dev_ratio,
        t_screen: r.t_screen,
        t_solve: r.t_solve,
        t_kkt: r.t_kkt,
        solver_converged: r.solver_converged,
        full_grad_sweeps: r.full_grad_sweeps,
        n_universe: r.n_universe.map(|v| v as usize),
        gap: r.gap,
        degraded_to,
    })
}

/// The interned `&'static str` for a strategy name, if it names one.
fn strategy_static_name(name: &str) -> Option<&'static str> {
    [
        Strategy::NoScreening,
        Strategy::StrongSet,
        Strategy::PreviousSet,
        Strategy::SafeOnly,
        Strategy::GapHybrid,
    ]
    .iter()
    .map(|s| s.name())
    .find(|n| *n == name)
}

/// Resume-time validation of the snapshot against the fit about to run:
/// fingerprint chain, strategy, shapes, prefix consistency. Every
/// mismatch is a typed error — a snapshot is never trusted past this.
/// (The dataset fingerprint was already checked by [`resume_path`].)
fn validate_snapshot(
    snap: &Snapshot,
    opts: &PathOptions,
    problem_fp: u64,
    grid_fp: u64,
    pt: usize,
    nm: usize,
    grid_len: usize,
) -> Result<(), CheckpointError> {
    let fail = |msg: String| Err(CheckpointError::Incompatible(msg));
    if snap.problem_fp != problem_fp {
        return fail(format!(
            "problem fingerprint {:016x} != expected {problem_fp:016x} (different y/family/shape)",
            snap.problem_fp
        ));
    }
    if snap.grid_fp != grid_fp {
        return fail(format!(
            "grid fingerprint {:016x} != expected {grid_fp:016x} (different lambda/sigma grid)",
            snap.grid_fp
        ));
    }
    if snap.strategy != opts.strategy.name() {
        return fail(format!(
            "snapshot strategy `{}` != requested `{}`",
            snap.strategy,
            opts.strategy.name()
        ));
    }
    if snap.pt as usize != pt || snap.nm as usize != nm {
        return fail(format!(
            "shape mismatch: snapshot (p·m {}, n·m {}) != problem (p·m {pt}, n·m {nm})",
            snap.pt, snap.nm
        ));
    }
    if snap.beta.len() != pt || snap.grad.len() != pt || snap.eta.len() != nm || snap.h.len() != nm
    {
        return fail("state vector lengths do not match the recorded shapes".to_string());
    }
    let steps = snap.next_step as usize;
    if steps == 0 || steps > grid_len {
        return fail(format!("next_step {steps} outside the σ grid (len {grid_len})"));
    }
    if snap.sigmas.len() != steps || snap.betas.len() != steps || snap.steps.len() != steps {
        return fail(format!(
            "recorded prefix ({}/{}/{} entries) inconsistent with next_step {steps}",
            snap.sigmas.len(),
            snap.betas.len(),
            snap.steps.len()
        ));
    }
    if opts.strategy.is_gap_driven() {
        match &snap.gap {
            None => return fail("gap-driven strategy but snapshot has no gap state".to_string()),
            Some(g) => {
                if g.ref_h.len() != nm || g.ref_gmag.len() != pt || g.grad_bound.len() != pt {
                    return fail("gap state vector lengths do not match the problem".to_string());
                }
            }
        }
    }
    Ok(())
}

/// The single σ-loop behind [`fit_path_seeded`], [`fit_path_checkpointed`]
/// and [`resume_path`] — one code path, so a resumed fit replays the
/// exact arithmetic an uninterrupted fit runs. `Err` is reachable only
/// when `resume` is `Some` (snapshot validation); plain fits are
/// infallible.
fn fit_path_driver(
    prob: &Problem,
    opts: &PathOptions,
    evaluator: &dyn FullGradient,
    seed: Option<&PathSeed>,
    ckpt: Option<&CheckpointConfig>,
    resume: Option<Snapshot>,
) -> Result<PathFit, CheckpointError> {
    let t_start = Instant::now();
    // Whole-fit span: the per-step spans below nest inside it, so the
    // trace profiler attributes driver overhead (grid setup, the closing
    // sweep) to the fit rather than to any step.
    let mut fit_span = crate::obs::trace::span("path_fit");
    let n = prob.n();
    let m_classes = prob.family.n_classes();
    let pt = prob.p_total();
    let lambda_base = opts.config.kind.sequence(pt);
    let par = opts.par();

    // Gradient at β = 0 (needed for σ_max and the first strong set).
    let mut eta = vec![0.0; n * m_classes];
    let mut h = vec![0.0; n * m_classes];
    let mut grad = vec![0.0; pt];
    let loss0 = state_at_zero(prob, evaluator, &eta, &mut h, &mut grad, par);

    let smax = sigma_max(&grad, &lambda_base);
    let ratio = opts.config.resolved_min_ratio(n, prob.p());
    let sigmas_all = sigma_grid(smax, ratio, opts.config.length);
    let dev_null = prob.family.deviance(loss0, &prob.y);

    let mut fit = PathFit {
        sigmas: Vec::new(),
        lambda_base: lambda_base.clone(),
        steps: Vec::new(),
        betas: Vec::new(),
        final_beta: vec![0.0; pt],
        total_violations: 0,
        stopped_early: None,
        wall_time: 0.0,
        final_grad: Vec::new(),
        total_grad_sweeps: 0.0,
    };

    // Step 0 (cold fits only — a resumed fit adopts its recorded prefix
    // below instead): β = 0 by construction of σ_max. Its recorded sweep
    // is the bootstrap full gradient `state_at_zero` just paid.
    if resume.is_none() {
    fit.sigmas.push(sigmas_all[0]);
    fit.betas.push(Vec::new());
    fit.steps.push(StepInfo {
        sigma: sigmas_all[0],
        n_active: 0,
        n_screened_rule: 0,
        n_fitted: 0,
        n_safe: opts.record_safe.then_some(0),
        violations: 0,
        refits: 0,
        solver_iterations: 0,
        deviance: dev_null,
        dev_ratio: 0.0,
        t_screen: 0.0,
        t_solve: 0.0,
        t_kkt: 0.0,
        solver_converged: true,
        full_grad_sweeps: 1.0,
        n_universe: None,
        gap: None,
        degraded_to: None,
    });
    fit.total_grad_sweeps += 1.0;
    }

    // Gap-driven strategies carry a dual state across steps: the sphere
    // reference starts at the exact β = 0 gradient just computed (a
    // resumed fit re-anchors it from the snapshot below).
    let mut gap_state = if opts.strategy.is_gap_driven() {
        Some(GapState::new(prob, opts, &h, &grad, loss0))
    } else {
        None
    };

    let mut beta_full = vec![0.0; pt];
    // Warm start: prime the first reduced solves with a prior solution on
    // this problem, and make (β, η, h, ∇f) mutually consistent at that
    // state so step 1's screening and the gap-safe diagnostic see one
    // coherent point. Correctness is unaffected (every step still solves
    // to the KKT tolerance); the win is fewer FISTA iterations on repeat
    // or refined requests. σ_max and the grid were already computed from
    // the β = 0 gradient above, so the grid is identical to a cold fit's.
    // (Skipped for single-point grids: with no step to solve, the final
    // state must remain the consistent β = 0 / ∇f(0) pair at σ_max.
    // Skipped on resume too: the snapshot IS the warm state.)
    if resume.is_none() && sigmas_all.len() > 1 {
        if let Some(s) = seed {
            if s.beta.len() == pt && s.grad.len() == pt {
                beta_full.copy_from_slice(&s.beta);
                grad.copy_from_slice(&s.grad);
                prob.eta_with(&beta_full, &mut eta, par);
                let seed_loss = prob.family.h_loss(&eta, &prob.y, &mut h);
                if let Some(gs) = &mut gap_state {
                    // The seed state is exact (seed gradients are always
                    // refreshed over every coefficient) — adopt it as the
                    // sphere reference: warm fits start with tight bounds.
                    gs.adopt_exact(&h, &grad, seed_loss);
                }
            }
        }
    }
    let mut prev_dev = dev_null;
    // scratch for scaled penalties and the screening-rule ordering,
    // reused across every path step
    let mut lam_prev = vec![0.0; pt];
    let mut lam_cur = vec![0.0; pt];
    let mut screen_ws = StrongWorkspace::default();
    // Column norms are invariant along the path: one sweep up front for
    // the gap-safe diagnostic, not one per step.
    let safe_col_norms: Vec<f64> = if opts.record_safe && prob.family == Family::Gaussian {
        prob.x.col_norms_with(par)
    } else {
        Vec::new()
    };
    // Pre-step snapshot buffers for the degradation ladder: a
    // non-converged solve is retried from the *previous* point under a
    // more conservative strategy, so the state it mutated must be
    // restorable. Allocated once; per step the cost is four memcpys —
    // no arithmetic touched, so healthy fits stay bitwise identical.
    let mut snap_beta = vec![0.0; pt];
    let mut snap_grad = vec![0.0; pt];
    let mut snap_eta = vec![0.0; n * m_classes];
    let mut snap_h = vec![0.0; n * m_classes];

    // Fingerprints pinning what a snapshot may be written for / resumed
    // against; computed only when durable state is in play, so plain
    // fits pay nothing here.
    let idents = if ckpt.is_some() || resume.is_some() {
        Some((
            checkpoint::problem_fingerprint(prob),
            checkpoint::grid_fingerprint(&lambda_base, &sigmas_all),
        ))
    } else {
        None
    };

    // --- resume (DESIGN.md §13) -------------------------------------------
    // Adopt the snapshot's recorded prefix and loop state wholesale, so
    // the σ-loop below continues exactly as if it had just finished step
    // `next_step − 1` itself. Every restored quantity is either copied
    // bitwise or (the screen-workspace ranking) recomputed by a pure
    // function of restored state.
    let start_m = if let Some(snap) = &resume {
        let (problem_fp, grid_fp) = idents.expect("resume always computes fingerprints");
        validate_snapshot(snap, opts, problem_fp, grid_fp, pt, n * m_classes, sigmas_all.len())?;
        fit.sigmas = snap.sigmas.clone();
        fit.betas = snap
            .betas
            .iter()
            .map(|s| s.iter().map(|&(i, v)| (i as usize, v)).collect())
            .collect();
        fit.steps = snap.steps.iter().map(rec_to_step).collect::<Result<_, _>>()?;
        fit.total_violations = snap.total_violations as usize;
        fit.total_grad_sweeps = snap.total_grad_sweeps;
        beta_full.copy_from_slice(&snap.beta);
        grad.copy_from_slice(&snap.grad);
        eta.copy_from_slice(&snap.eta);
        h.copy_from_slice(&snap.h);
        prev_dev = fit.steps.last().map_or(dev_null, |s| s.deviance);
        // Between steps the workspace always holds the ranking of the
        // current gradient (the step's last KKT sweep ranked it); `rank`
        // is a pure function of `grad`, so this reproduces it bitwise.
        screen_ws.rank(&grad);
        if let Some(gs) = &mut gap_state {
            let gsnap = snap.gap.as_ref().expect("validated: gap-driven snapshot carries gap state");
            gs.restore_snapshot(gsnap);
        }
        obsreg::CKPT_RESUMES.inc();
        snap.next_step as usize
    } else {
        1
    };

    for m in start_m..sigmas_all.len() {
        // Cooperative cancellation between σ-steps: a fired token (an
        // expired deadline) keeps every step already recorded and stops.
        if opts.is_cancelled() {
            fit.stopped_early = Some("cancelled");
            break;
        }
        // One trace span per σ-step carrying the StepInfo fields; inert
        // (a load + branch) unless `--trace` enabled the sink.
        let mut step_span = crate::obs::trace::span("path_step");
        let sig_prev = sigmas_all[m - 1];
        let sig = sigmas_all[m];
        for i in 0..pt {
            lam_prev[i] = lambda_base[i] * sig_prev;
            lam_cur[i] = lambda_base[i] * sig;
        }

        // --- screening phase --------------------------------------------
        let t0 = Instant::now();
        let prev_support = support(&beta_full);
        let (rule_set, n_screened_rule, e_set, gap_screen) = match &mut gap_state {
            Some(gs) => {
                let mut sc = gap_screening(
                    prob,
                    opts,
                    gs,
                    &lam_prev,
                    &lam_cur,
                    &prev_support,
                    &beta_full,
                    &h,
                    &mut screen_ws,
                );
                let n = sc.rule_set.len();
                // Take, don't clone: the GapScreen's rule_set is not read
                // again (solve_with_gap consumes e_set/universe/gap_abs),
                // and `e_set` is only consumed by the safeguarded solve
                // arm, which is unreachable when a GapScreen exists.
                let rule = std::mem::take(&mut sc.rule_set);
                (rule, n, Vec::new(), Some(sc))
            }
            None => {
                let (r, n, e) = screening_sets(
                    opts.strategy,
                    pt,
                    &grad,
                    &lam_prev,
                    &lam_cur,
                    &prev_support,
                    &mut screen_ws,
                );
                (r, n, e, None)
            }
        };
        // Gap-safe comparison (Gaussian only): |Xᵀr| = |grad| for OLS.
        // Skipped for the gap-driven strategies, whose `grad` is exact
        // only on the swept universe — they report `n_universe` instead.
        let n_safe = if opts.record_safe
            && prob.family == Family::Gaussian
            && gap_state.is_none()
        {
            let r_norm_sq = {
                // r = y − Xβ = −h at the previous solution
                sq_norm(&h)
            };
            let y_dot_r = -crate::linalg::dense::dot(&prob.y, &h);
            let primal = 0.5 * r_norm_sq
                + crate::slope::sorted::sl1_norm(&beta_full, &lam_cur);
            Some(
                gap_safe_set(&grad, r_norm_sq, primal, &safe_col_norms, &lam_cur, y_dot_r)
                    .len(),
            )
        } else {
            opts.record_safe.then_some(pt)
        };
        let t_screen = t0.elapsed().as_secs_f64();

        // --- solve + certificate loop -------------------------------------
        snap_beta.copy_from_slice(&beta_full);
        snap_grad.copy_from_slice(&grad);
        snap_eta.copy_from_slice(&eta);
        snap_h.copy_from_slice(&h);
        let mut out = match (&mut gap_state, gap_screen) {
            (Some(gs), Some(sc)) => solve_with_gap(
                prob,
                opts,
                evaluator,
                &lambda_base,
                sig,
                &lam_cur,
                sc.e_set,
                sc.universe,
                sc.gap_abs,
                gs,
                &mut beta_full,
                &mut eta,
                &mut h,
                &mut grad,
                &mut screen_ws,
            ),
            _ => solve_with_safeguard(
                prob,
                opts,
                evaluator,
                &lambda_base,
                sig,
                &lam_cur,
                &rule_set,
                &prev_support,
                e_set,
                &mut beta_full,
                &mut eta,
                &mut h,
                &mut grad,
                &mut screen_ws,
            ),
        };
        // --- degradation ladder (DESIGN.md §12) ---------------------------
        // A step whose certificate stalled (the MAX_GAP_ROUNDS bail) or
        // whose inner solves exhausted max_iter is retried from the
        // pre-step snapshot under the next-most-conservative strategy, so
        // a heuristic failure degrades into a slower-but-sound solve
        // instead of surfacing as a non-converged step. Cancelled fits
        // are never rescued — their deadline already fired.
        let mut degraded_to: Option<&'static str> = None;
        let mut rung = opts.strategy;
        while opts.degrade && !out.converged && !opts.is_cancelled() {
            let next = match ladder_next(rung) {
                Some(s) => s,
                None => break, // already at the full solve: report honestly
            };
            rung = next;
            beta_full.copy_from_slice(&snap_beta);
            grad.copy_from_slice(&snap_grad);
            eta.copy_from_slice(&snap_eta);
            h.copy_from_slice(&snap_h);
            // The workspace ranking tracks the *failed* attempt's
            // gradient; re-rank on the restored one before re-screening.
            screen_ws.rank(&grad);
            let rescue_opts = PathOptions { strategy: next, ..opts.clone() };
            let (r_rule, _r_n, r_e) = screening_sets(
                next,
                pt,
                &grad,
                &lam_prev,
                &lam_cur,
                &prev_support,
                &mut screen_ws,
            );
            let mut rescue = solve_with_safeguard(
                prob,
                &rescue_opts,
                evaluator,
                &lambda_base,
                sig,
                &lam_cur,
                &r_rule,
                &prev_support,
                r_e,
                &mut beta_full,
                &mut eta,
                &mut h,
                &mut grad,
                &mut screen_ws,
            );
            obsreg::PATH_DEGRADED_STEPS.inc();
            degraded_to = Some(next.name());
            // Work accounting stays cumulative across attempts; the
            // failed attempt's set bookkeeping is discarded with its
            // solution (it described a state that no longer exists).
            rescue.solver_iterations += out.solver_iterations;
            rescue.refits += out.refits;
            rescue.sweeps += out.sweeps;
            rescue.t_solve += out.t_solve;
            rescue.t_kkt += out.t_kkt;
            out = rescue;
        }
        if degraded_to.is_some() {
            if let Some(gs) = &mut gap_state {
                // The rescue ran outside the gap machinery; its closing
                // full-gradient sweep left `grad` exact at the accepted
                // solution, so re-anchor the dual state there.
                gs.adopt_exact(&h, &grad, out.loss);
            }
        }
        let loss = out.loss;
        let e_set = out.e_set;
        let (refits, solver_iterations) = (out.refits, out.solver_iterations);
        let (t_solve, t_kkt) = (out.t_solve, out.t_kkt);
        // Strong-rule violations (§2.2.3): active predictors the *rule*
        // discarded. For the previous-set algorithm, stage-1 additions come
        // from inside the strong set — they are failures of the
        // previous-set guess, not of the rule — so only predictors outside
        // S(λ⁽ᵐ⁺¹⁾) ∪ T(λ⁽ᵐ⁾) count.
        let rule_cover = union_sorted(&rule_set, &prev_support);
        let violations_total = diff_sorted(&out.added_by_kkt, &rule_cover)
            .iter()
            .filter(|&&c| beta_full[c] != 0.0)
            .count();

        // --- record -------------------------------------------------------
        let dev = prob.family.deviance(loss, &prob.y);
        let dev_ratio = if dev_null > 0.0 { 1.0 - dev / dev_null } else { 0.0 };
        let active = support(&beta_full);
        fit.sigmas.push(sig);
        fit.betas
            .push(active.iter().map(|&i| (i, beta_full[i])).collect());
        fit.steps.push(StepInfo {
            sigma: sig,
            n_active: active.len(),
            n_screened_rule,
            n_fitted: e_set.len(),
            n_safe,
            violations: violations_total,
            refits,
            solver_iterations,
            deviance: dev,
            dev_ratio,
            t_screen,
            t_solve,
            t_kkt,
            solver_converged: out.converged,
            full_grad_sweeps: out.sweeps,
            n_universe: out.n_universe,
            gap: out.gap,
            degraded_to,
        });
        fit.total_violations += violations_total;
        fit.total_grad_sweeps += out.sweeps;
        obsreg::PATH_STEPS.inc();
        obsreg::SCREEN_RULE_COLS.add(n_screened_rule as u64);
        if let Some(ns) = n_safe {
            obsreg::SCREEN_SAFE_COLS.add(ns as u64);
        }
        obsreg::SCREEN_UNIVERSE_COLS.add(out.n_universe.unwrap_or(pt) as u64);
        obsreg::KKT_VIOLATIONS.add(violations_total as u64);
        obsreg::KKT_REFITS.add(refits as u64);
        if step_span.active() {
            step_span.u("step", m as u64);
            step_span.f("sigma", sig);
            step_span.u("n_active", active.len() as u64);
            step_span.u("n_screened_rule", n_screened_rule as u64);
            step_span.u("n_fitted", e_set.len() as u64);
            step_span.u("violations", violations_total as u64);
            step_span.u("refits", refits as u64);
            step_span.u("solver_iterations", solver_iterations as u64);
            step_span.f("dev_ratio", dev_ratio);
            step_span.f("full_grad_sweeps", out.sweeps);
            if let Some(nu) = out.n_universe {
                step_span.u("n_universe", nu as u64);
            }
            if let Some(g) = out.gap {
                step_span.f("gap", g);
            }
            if let Some(d) = degraded_to {
                step_span.s("degraded_to", d);
            }
            step_span.f("t_screen", t_screen);
            step_span.f("t_solve", t_solve);
            step_span.f("t_kkt", t_kkt);
        }

        // --- early termination (§3.1.2) ------------------------------------
        // Decided before the snapshot below: a checkpoint's `next_step`
        // promises more work, and an early-stopped fit is already
        // complete — snapshotting it would make a resume run *past* the
        // stop an uninterrupted fit honored.
        let mut stop: Option<&'static str> = None;
        if opts.config.stop_on_saturation && unique_nonzero_magnitudes(&beta_full) > n {
            stop = Some("unique magnitudes exceed n");
        } else if opts.config.stop_on_dev_change
            && dev_null > 0.0
            && ((prev_dev - dev) / dev_null).abs() < 1e-5
        {
            stop = Some("deviance change < 1e-5");
        } else if opts.config.stop_on_dev_ratio && dev_ratio > 0.995 {
            stop = Some("deviance ratio > 0.995");
        }

        // --- durable snapshot (DESIGN.md §13) ------------------------------
        // Cadence writes every `every` steps; a degradation event always
        // snapshots (that state is exactly what a post-mortem wants, and
        // the next crash may be related). The write only *reads* fit
        // state, so checkpointed fits stay bitwise identical to plain
        // ones; a failed write is logged, not fatal — the previous
        // snapshot (if any) remains valid.
        if stop.is_none() {
            if let Some(cfg) = ckpt {
                if m % cfg.every.max(1) == 0 || degraded_to.is_some() {
                    let (problem_fp, grid_fp) = idents.expect("ckpt always computes fingerprints");
                    let snap = Snapshot {
                        dataset_fp: cfg.dataset_fingerprint,
                        problem_fp,
                        grid_fp,
                        strategy: opts.strategy.name().to_string(),
                        next_step: (m + 1) as u64,
                        pt: pt as u64,
                        nm: (n * m_classes) as u64,
                        beta: beta_full.clone(),
                        grad: grad.clone(),
                        eta: eta.clone(),
                        h: h.clone(),
                        total_violations: fit.total_violations as u64,
                        total_grad_sweeps: fit.total_grad_sweeps,
                        sigmas: fit.sigmas.clone(),
                        betas: fit
                            .betas
                            .iter()
                            .map(|s| s.iter().map(|&(i, v)| (i as u64, v)).collect())
                            .collect(),
                        steps: fit.steps.iter().map(step_to_rec).collect(),
                        gap: gap_state.as_ref().map(GapState::snapshot),
                    };
                    let mut ck_span = crate::obs::trace::span("checkpoint");
                    match checkpoint::write_atomic(&cfg.path, &snap) {
                        Ok(bytes) => {
                            if ck_span.active() {
                                ck_span.u("step", m as u64);
                                ck_span.u("bytes", bytes);
                            }
                            fault::on_checkpoint_write(&cfg.path);
                        }
                        Err(e) => eprintln!("checkpoint: write failed at step {m}: {e}"),
                    }
                }
            }
            // Chaos kill point: fires after the step — and, in a
            // checkpointed fit, after its snapshot — has landed.
            fault::on_path_step(m as u64);
        }
        if let Some(why) = stop {
            fit.stopped_early = Some(why);
            break;
        }
        prev_dev = dev;
    }

    // Gap-driven fits may have swept only a partial universe on the last
    // step; the warm-start contract (`PathFit::final_grad` is exact over
    // every coefficient) costs them one closing full sweep.
    if let Some(gs) = &mut gap_state {
        if !gs.grad_is_exact {
            evaluator.full_grad_with(&beta_full, &h, &mut grad, par);
            note_full_sweep(pt);
            gs.grad_is_exact = true;
            fit.total_grad_sweeps += 1.0;
        }
    }
    fit.final_beta = beta_full;
    fit.final_grad = grad;
    fit.wall_time = t_start.elapsed().as_secs_f64();
    if fit_span.active() {
        fit_span.s("strategy", opts.strategy.name());
        fit_span.u("p", pt as u64);
        fit_span.u("n", n as u64);
        fit_span.u("steps", fit.steps.len() as u64);
        fit_span.u("total_violations", fit.total_violations as u64);
        fit_span.f("total_grad_sweeps", fit.total_grad_sweeps);
        fit_span.u("warm", seed.is_some() as u64);
        fit_span.u("resumed", resume.is_some() as u64);
    }
    Ok(fit)
}

/// The screening-phase set selection shared by the path driver and
/// [`fit_point`]: `(rule_set, n_screened_rule, e_set)` for one step from
/// the previous point's gradient and support. `ws` is the reusable fused
/// sweep workspace (one per fit, reused every step): when the preceding
/// KKT check already ranked this gradient — always the case between path
/// steps — the strong set consumes that ranking instead of re-sorting,
/// so each σ-step orders its p-length gradient exactly once.
fn screening_sets(
    strategy: Strategy,
    pt: usize,
    grad: &[f64],
    lam_prev: &[f64],
    lam_cur: &[f64],
    prev_support: &[usize],
    ws: &mut StrongWorkspace,
) -> (Vec<usize>, usize, Vec<usize>) {
    let rule_set = match strategy {
        Strategy::NoScreening => (0..pt).collect::<Vec<_>>(),
        _ => {
            if !ws.is_ranked() {
                ws.rank(grad);
            }
            ws.strong_set_ranked(lam_prev, lam_cur)
        }
    };
    let n_screened_rule = match strategy {
        Strategy::NoScreening => pt,
        _ => rule_set.len(),
    };
    let e_set = match strategy {
        Strategy::NoScreening => rule_set.clone(),
        Strategy::StrongSet => union_sorted(&rule_set, prev_support),
        Strategy::PreviousSet => prev_support.to_vec(),
        Strategy::SafeOnly | Strategy::GapHybrid => {
            unreachable!("gap-driven strategies screen through gap_screening")
        }
    };
    (rule_set, n_screened_rule, e_set)
}

/// Outcome of one safeguarded (or gap-certified) solve at a single σ.
struct SolveOutcome {
    /// Smooth loss at the final solution.
    loss: f64,
    /// Final fitted set (ascending coefficient indices).
    e_set: Vec<usize>,
    /// Predictors added by failed KKT checks across all rounds.
    added_by_kkt: Vec<usize>,
    /// Solve/refit rounds (1 = no violations).
    refits: usize,
    /// Total inner FISTA iterations.
    solver_iterations: usize,
    /// Seconds in the reduced solver.
    t_solve: f64,
    /// Seconds in full-gradient + KKT checks.
    t_kkt: f64,
    /// Whether every inner solve met its certificate before `max_iter`.
    converged: bool,
    /// Full-design-equivalent gradient sweeps (1.0 per full sweep,
    /// `|U|/p` per universe sweep).
    sweeps: f64,
    /// Final safe-universe size (gap-driven loop only).
    n_universe: Option<usize>,
    /// Certified duality gap at acceptance (gap-driven loop only).
    gap: Option<f64>,
}

/// Whether the packed engine can beat the gather kernels on this
/// design. Dense: always (same flops, better locality). Sparse:
/// densifying screened columns multiplies per-iteration kernel work by
/// roughly `1/density`, so only designs dense enough to repay the slab
/// stream qualify — a dorothea-like 1%-dense design stays on the sparse
/// gather kernels, which touch only stored nonzeros.
fn packing_profitable(prob: &Problem) -> bool {
    match &prob.x {
        crate::linalg::Design::Dense(_) => true,
        crate::linalg::Design::Sparse(s) => {
            let cells = s.nrows().saturating_mul(s.ncols()).max(1);
            // density ≥ 25%: dense streaming beats indexed access there
            4 * s.nnz() >= cells
        }
    }
}

/// Build the reduced view for one safeguarded solve: packed (consulting
/// the pack cache when one is attached) or gather, per the options. A
/// set covering every coefficient gains nothing from packing — it would
/// just duplicate the design — so it stays on the gather engine, as do
/// designs too sparse to repay densification ([`packing_profitable`]).
/// Returns the view plus whether it was adopted from the cache (an
/// adopted, never-appended view needs no re-deposit).
fn build_reduced<'a>(
    prob: &'a Problem,
    e_set: Vec<usize>,
    opts: &PathOptions,
) -> (Reduced<'a>, bool) {
    let par = opts.par();
    if opts.packing && e_set.len() < prob.p_total() && packing_profitable(prob) {
        if let Some(cache) = &opts.pack_cache {
            if let Some(set) = cache.lookup(&e_set) {
                // Release-mode identity guard: a cache that (against its
                // contract) saw a different design must not serve slabs
                // of the wrong shape — refuse the hit and pack fresh.
                if set.packs.iter().all(|pk| pk.nrows() == prob.n()) {
                    return (Reduced::from_cached(prob, &set, par), true);
                }
            }
        }
        (Reduced::new(prob, e_set).with_par(par).packed(), false)
    } else {
        (Reduced::new(prob, e_set).with_par(par), false)
    }
}

/// The solve + KKT safeguard loop shared by [`fit_path_seeded`] (per path
/// step) and [`fit_point`] (per request): repeatedly solve the reduced
/// problem on `e_set`, check the Theorem-1 conditions on the true full
/// gradient, and widen `e_set` until no violation remains. On return
/// `beta_full`, `eta`, `h` and `grad` hold the state at the final
/// solution, and `ws` holds the final gradient's magnitude ranking (which
/// the next step's strong set consumes — the fused sweep).
///
/// The reduced view is built **once** per step; violator admissions
/// append to it (packed slabs grow incrementally, no re-pack), and on a
/// cache-assisted fit the final pack is deposited for the next fit with
/// the same support.
#[allow(clippy::too_many_arguments)]
fn solve_with_safeguard(
    prob: &Problem,
    opts: &PathOptions,
    evaluator: &dyn FullGradient,
    lambda_base: &[f64],
    sig: f64,
    lam_cur: &[f64],
    rule_set: &[usize],
    prev_support: &[usize],
    mut e_set: Vec<usize>,
    beta_full: &mut [f64],
    eta: &mut [f64],
    h: &mut [f64],
    grad: &mut [f64],
    ws: &mut StrongWorkspace,
) -> SolveOutcome {
    let pt = prob.p_total();
    let mut t_kkt = 0.0;
    // Predictors added by failed KKT checks; a *violation* in the
    // paper's sense (§2.2.3) is such a predictor that is genuinely
    // active at the step's final solution — KKT flags that refit back
    // to zero are solver-tolerance noise, not rule failures.
    let mut added_by_kkt: Vec<usize> = Vec::new();
    let mut refits = 0;
    let mut solver_iterations = 0;
    let mut converged = true;
    let mut sweeps = 0.0f64;
    let kkt_thresh = opts.kkt_tol * sig * lambda_base[0].max(1e-12);
    // Alg 4 checks the strong set first; track which stage we are in.
    let mut checked_full = matches!(
        opts.strategy,
        Strategy::NoScreening | Strategy::StrongSet
    );
    let par = opts.par();
    let t0 = Instant::now();
    let (mut reduced, adopted) = build_reduced(prob, e_set.clone(), opts);
    let mut t_solve = t0.elapsed().as_secs_f64();
    let mut widened = false;
    let mut loss;
    loop {
        // Cooperative cancellation between safeguard rounds: every
        // completed round leaves (β, η, h, ∇f) mutually consistent, so
        // breaking here returns coherent partial state. The first round
        // always runs (its inner solve exits within one iteration once
        // the token has fired), keeping `loss` and `grad` initialized.
        if refits > 0 && opts.is_cancelled() {
            converged = false;
            break;
        }
        refits += 1;
        let t1 = Instant::now();
        let warm: Vec<f64> = reduced.coefs.iter().map(|&c| beta_full[c]).collect();
        // The inner solve must be at least as accurate as the
        // violation threshold, else solver noise shows up as phantom
        // violations (§2.2.3 counts would be meaningless).
        let mut fista_cfg = opts.fista.clone();
        if fista_cfg.kkt_tol_abs.is_none() {
            fista_cfg.kkt_tol_abs = Some(kkt_thresh);
        }
        if fista_cfg.cancel.is_none() {
            fista_cfg.cancel = opts.cancel.clone();
        }
        let res = solve(
            &reduced,
            &scale_prefix(lambda_base, sig, reduced.len()),
            Some(&warm),
            &fista_cfg,
        );
        solver_iterations += res.iterations;
        converged &= res.converged;
        loss = res.loss;
        reduced.scatter(&res.beta, beta_full);
        t_solve += t1.elapsed().as_secs_f64();

        // Full gradient at the candidate. The solver already computed
        // η = X_E β_E at its solution (off-E coefficients are zero), so
        // the KKT sweep reuses it — for the Gaussian family this is the
        // cached residual: only the parallel Xᵀh product remains. The
        // resulting gradient is ranked once (`ws.rank`) and that ordering
        // serves both the violation check here and, after the loop, the
        // next step's strong set.
        let t2 = Instant::now();
        eta.copy_from_slice(&res.eta);
        prob.family.h_loss(eta, &prob.y, h);
        evaluator.full_grad_with(beta_full, h, grad, par);
        note_full_sweep(pt);
        sweeps += 1.0;

        // Violation detection: Algorithm 1 on the true gradient
        // (Prop. 1) restricted to the stage's check set.
        ws.rank(grad);
        let candidate_set = ws.kkt_flagged_ranked(lam_cur, kkt_thresh);
        let viols: Vec<usize> = match opts.strategy {
            Strategy::PreviousSet if !checked_full => diff_sorted(
                &intersect_sorted(&candidate_set, &union_sorted(rule_set, prev_support)),
                &e_set,
            ),
            _ => diff_sorted(&candidate_set, &e_set),
        };
        t_kkt += t2.elapsed().as_secs_f64();

        if viols.is_empty() {
            if checked_full {
                break;
            }
            // Alg 4: strong set is clean — escalate to the full check.
            checked_full = true;
            continue;
        }
        let t3 = Instant::now();
        added_by_kkt = union_sorted(&added_by_kkt, &viols);
        e_set = union_sorted(&e_set, &viols);
        let mut grow = viols;
        // Anti-creep escalation: when the violation loop keeps finding
        // more predictors round after round (heavy clustering regimes,
        // §3.2.3's "almost all predictors enter at the second step"),
        // widen E to the whole strong-set cover at once instead of
        // paying one big re-solve per trickle of violations.
        if refits >= 3 && opts.strategy == Strategy::PreviousSet {
            let cover = union_sorted(rule_set, prev_support);
            let extra = diff_sorted(&cover, &e_set);
            if !extra.is_empty() {
                e_set = union_sorted(&e_set, &extra);
                grow = union_sorted(&grow, &extra);
            }
        }
        // Incremental admission: only the violator columns join the
        // packed slab — the columns already packed are untouched.
        reduced.append(&grow);
        widened = true;
        t_solve += t3.elapsed().as_secs_f64();
    }
    // Deposit the finished pack so the next fit with this support (warm
    // serve requests, repeated path sweeps) skips packing entirely. An
    // adopted view that never widened is already cached verbatim — no
    // point paying the snapshot and the cache lock for a no-op overwrite.
    if !adopted || widened {
        if let Some(cache) = &opts.pack_cache {
            if let Some(set) = reduced.packed_set() {
                cache.store(set);
            }
        }
    }
    SolveOutcome {
        loss,
        e_set,
        added_by_kkt,
        refits,
        solver_iterations,
        t_solve,
        t_kkt,
        converged,
        sweeps,
        n_universe: None,
        gap: None,
    }
}

/// Upper bound on rounds of the gap-certified loop. The loop provably
/// makes progress every round (either the working set grows — bounded by
/// the universe — or the inner tolerance shrinks geometrically), so this
/// only fires when the gap target sits below the numeric floor; the
/// failure then surfaces as `solver_converged = false`, never as a
/// silent bad certificate.
const MAX_GAP_ROUNDS: usize = 40;

/// The degradation ladder (DESIGN.md §12): the next-most-conservative
/// strategy to retry a non-converged step under. Order:
/// hybrid/previous → strong → full (no-screening). The last rung fits
/// every predictor under the KKT safeguard — trivially sound, since
/// nothing is discarded — so there is nowhere further to go: `None`
/// means the non-convergence must be reported as-is.
fn ladder_next(s: Strategy) -> Option<Strategy> {
    match s {
        Strategy::GapHybrid | Strategy::PreviousSet => Some(Strategy::StrongSet),
        Strategy::StrongSet | Strategy::SafeOnly => Some(Strategy::NoScreening),
        Strategy::NoScreening => None,
    }
}

/// Cross-step dual state of the gap-driven strategies: the sphere-test
/// screener (reference dual point + cached reference magnitudes), the
/// per-coefficient gradient-magnitude bounds at the *current* residual,
/// and the loss there. See DESIGN.md §10.
struct GapState {
    screener: SafeScreener,
    /// Upper bounds on `|∇f_j|` at the current residual: exact values on
    /// the coordinates the last sweep covered, reference-sphere bounds
    /// everywhere else. Always consistent with the `h` the caller holds.
    grad_bound: Vec<f64>,
    /// `f(β)` at the current point.
    loss: f64,
    /// True while `grad` (the caller's full gradient buffer) is exact
    /// over *every* coefficient — set by full sweeps, cleared by
    /// universe sweeps.
    grad_is_exact: bool,
    /// Gather scratch for universe sweeps.
    scratch: Vec<f64>,
    /// Per-class column / coefficient lists for universe sweeps —
    /// reused across rounds so the sweep itself allocates nothing.
    cols: Vec<usize>,
    coefs: Vec<usize>,
    /// Sort buffer for the dual feasibility magnitudes (length `p·m`).
    mags: Vec<f64>,
}

impl GapState {
    /// Build from an exact state: `h`/`grad`/`loss` at one point, with
    /// `grad` covering every coefficient (the β = 0 bootstrap, or a
    /// seed's refreshed gradient). Column norms come from
    /// [`PathOptions::col_norms`] when a valid set is attached (the
    /// serve registry's per-dataset cache), else from one fresh sweep.
    fn new(prob: &Problem, opts: &PathOptions, h: &[f64], grad: &[f64], loss: f64) -> Self {
        let screener = match &opts.col_norms {
            // Release-mode guard like the pack cache's: norms of the
            // wrong shape must not poison the sphere tests. The Arc is
            // shared, not copied — per-request fits stay O(1) here.
            Some(norms) if norms.len() == prob.p() => {
                SafeScreener::from_norms(prob.p(), Arc::clone(norms))
            }
            _ => SafeScreener::new(prob, opts.par()),
        };
        let mut gs = Self {
            screener,
            grad_bound: vec![0.0; grad.len()],
            loss,
            grad_is_exact: true,
            scratch: Vec::new(),
            cols: Vec::new(),
            coefs: Vec::new(),
            mags: vec![0.0; grad.len()],
        };
        gs.adopt_exact(h, grad, loss);
        gs
    }

    /// Adopt an exact full-gradient state as both the current bounds and
    /// the sphere reference.
    fn adopt_exact(&mut self, h: &[f64], grad: &[f64], loss: f64) {
        self.screener.set_reference(h, grad);
        for (b, g) in self.grad_bound.iter_mut().zip(grad) {
            *b = g.abs();
        }
        self.loss = loss;
        self.grad_is_exact = true;
    }

    /// Serializable copy of the dual state for a checkpoint (scratch
    /// buffers excluded — they carry no cross-step information).
    fn snapshot(&self) -> GapSnap {
        let (ref_h, ref_gmag) =
            self.screener.reference().expect("gap state always holds a reference");
        GapSnap {
            ref_h: ref_h.to_vec(),
            ref_gmag: ref_gmag.to_vec(),
            grad_bound: self.grad_bound.clone(),
            loss: self.loss,
            grad_is_exact: self.grad_is_exact,
        }
    }

    /// Restore from a checkpointed [`GapSnap`]. `set_reference` passes
    /// the magnitudes through `|·|` again — idempotent on the stored
    /// absolute values — so the reconstructed screener is bitwise
    /// identical to the one that was snapshotted.
    fn restore_snapshot(&mut self, g: &GapSnap) {
        self.screener.set_reference(&g.ref_h, &g.ref_gmag);
        self.grad_bound.copy_from_slice(&g.grad_bound);
        self.loss = g.loss;
        self.grad_is_exact = g.grad_is_exact;
    }
}

/// One step's screening decision under a gap-driven strategy.
struct GapScreen {
    /// Heuristic rule set actually fitted (strong ∩ universe; the whole
    /// universe for [`Strategy::SafeOnly`]).
    rule_set: Vec<usize>,
    /// Initial working set.
    e_set: Vec<usize>,
    /// Sphere-test survivors at this σ (always ⊇ the previous support) —
    /// the set every gradient sweep of the step runs over.
    universe: Vec<usize>,
    /// Absolute gap acceptance threshold for the step, resolved from the
    /// warm point's primal value.
    gap_abs: f64,
}

/// `J(β; λ)` when the support is already known: only nonzero entries
/// contribute, and a vector with `s` nonzeros takes the `s` largest
/// weights — no full-length sort.
fn sparse_sl1(beta: &[f64], support: &[usize], lambda: &[f64]) -> f64 {
    let mut mags: Vec<f64> = support.iter().map(|&j| beta[j].abs()).collect();
    mags.sort_unstable_by(|a, b| b.total_cmp(a));
    mags.iter().zip(lambda).map(|(m, l)| m * l).sum()
}

/// Binary-search membership in an ascending index set.
fn contains_sorted(set: &[usize], x: usize) -> bool {
    set.binary_search(&x).is_ok()
}

/// Gradient sweep restricted to `universe` (ascending flattened
/// coefficient indices): writes `Xᵀh` into `grad` at exactly those
/// positions, through the subset kernels of the parallel backend.
/// Entries outside the universe are left untouched — consumers read
/// them through [`GapState::grad_bound`], never from `grad`. All
/// working buffers (`scratch`/`cols`/`coefs`) are caller-owned and
/// reused across rounds, so a sweep allocates nothing once warm.
#[allow(clippy::too_many_arguments)]
fn universe_gradient(
    prob: &Problem,
    universe: &[usize],
    h: &[f64],
    grad: &mut [f64],
    par: ParConfig,
    scratch: &mut Vec<f64>,
    cols: &mut Vec<usize>,
    coefs: &mut Vec<usize>,
) {
    let n = prob.n();
    let p = prob.p();
    let m = prob.family.n_classes();
    for l in 0..m {
        cols.clear();
        coefs.clear();
        for &c in universe {
            if c / p == l {
                cols.push(c % p);
                coefs.push(c);
            }
        }
        if cols.is_empty() {
            continue;
        }
        if scratch.len() < cols.len() {
            scratch.resize(cols.len(), 0.0);
        }
        let out = &mut scratch[..cols.len()];
        prob.x.gemv_t_subset_with(cols, &h[l * n..(l + 1) * n], out, par);
        for (o, &c) in out.iter().zip(coefs.iter()) {
            grad[c] = *o;
        }
    }
}

/// The screening phase of a gap-driven step, evaluated at the previous
/// point's state (`beta_full`, `h`, `gs.loss`, `gs.grad_bound` all
/// mutually consistent):
///
/// 1. duality gap of the warm point **for this step's penalty** — no
///    design product: magnitudes come from the bound vector;
/// 2. sphere test at radius `√(2·L·gap)` → the step's safe universe
///    (a *certified* superset of this σ's support);
/// 3. the strong rule on the bounded magnitudes, clipped to the
///    universe (skipped for [`Strategy::SafeOnly`], whose working set
///    is the whole universe).
fn gap_screening(
    prob: &Problem,
    opts: &PathOptions,
    gs: &mut GapState,
    lam_prev: &[f64],
    lam_cur: &[f64],
    prev_support: &[usize],
    beta_full: &[f64],
    h: &[f64],
    ws: &mut StrongWorkspace,
) -> GapScreen {
    let pt = prob.p_total();
    let penalty = sparse_sl1(beta_full, prev_support, lam_cur);
    // One O(p log p) ordering per step, shared by the gap's feasibility
    // magnitudes and (for the hybrid) the strong rule below — the fused
    // sweep, same as the KKT-safeguarded strategies.
    ws.rank(&gs.grad_bound);
    ws.ranked_magnitudes_into(&mut gs.mags);
    let gr = crate::slope::dual::duality_gap(
        prob.family,
        &prob.y,
        h,
        gs.loss,
        penalty,
        &gs.mags,
        lam_cur,
    );
    let gap_abs = opts.gap_tol * gr.primal.abs().max(1.0);
    let lam_min = lam_cur.last().copied().unwrap_or(0.0);
    let universe: Vec<usize> = match SafeScreener::radius(gr.gap, prob.family.hessian_bound()) {
        Some(radius) if gs.screener.has_reference() => {
            let kept: Vec<usize> = (0..pt)
                .filter(|&j| gs.screener.keeps(gs.grad_bound[j], j, gr.scale, radius, lam_min))
                .collect();
            // The previous support stays fittable regardless: its
            // members' warm values seed the solve, and keeping them
            // costs nothing when the certificate says they are zero —
            // the solve just returns them to zero.
            union_sorted(&kept, prev_support)
        }
        _ => (0..pt).collect(),
    };
    let (rule_set, e_set) = match opts.strategy {
        Strategy::SafeOnly => (universe.clone(), universe.clone()),
        _ => {
            // Consumes the ranking established above.
            let rule = ws.strong_set_ranked(lam_prev, lam_cur);
            let rule_set = intersect_sorted(&rule, &universe);
            let e_set = union_sorted(&rule_set, prev_support);
            (rule_set, e_set)
        }
    };
    GapScreen { rule_set, e_set, universe, gap_abs }
}

/// The gap-certified working-set loop (DESIGN.md §10) shared by the path
/// driver and [`fit_point`] for [`Strategy::GapHybrid`] /
/// [`Strategy::SafeOnly`]:
///
/// repeat — solve the reduced problem on `E` (KKT- and inner-gap
/// certified), sweep the gradient over the safe *universe* only, compute
/// the global duality gap (bounds stand in for the discarded
/// coordinates' magnitudes — conservative, hence sound), and either
/// accept (`gap ≤ gap_abs`), admit the top-K ranked violators into `E`,
/// or tighten the inner tolerance when no violator exists. The sphere
/// test re-runs with each fresh radius, so the universe only shrinks
/// within the step.
///
/// On return `beta_full`/`eta`/`h` hold the accepted state; `grad` is
/// exact on the universe (and everywhere, after a full-sweep round —
/// see [`GapState::grad_is_exact`]).
#[allow(clippy::too_many_arguments)]
fn solve_with_gap(
    prob: &Problem,
    opts: &PathOptions,
    evaluator: &dyn FullGradient,
    lambda_base: &[f64],
    sig: f64,
    lam_cur: &[f64],
    mut e_set: Vec<usize>,
    mut universe: Vec<usize>,
    gap_abs: f64,
    gs: &mut GapState,
    beta_full: &mut [f64],
    eta: &mut [f64],
    h: &mut [f64],
    grad: &mut [f64],
    ws: &mut StrongWorkspace,
) -> SolveOutcome {
    let pt = prob.p_total();
    let par = opts.par();
    let kkt_thresh = opts.kkt_tol * sig * lambda_base[0].max(1e-12);
    let lam_min = lam_cur.last().copied().unwrap_or(0.0);
    let mut added_by_kkt: Vec<usize> = Vec::new();
    let mut refits = 0usize;
    let mut solver_iterations = 0usize;
    let mut sweeps = 0.0f64;
    let mut converged = true;
    let mut t_kkt = 0.0;
    let t0 = Instant::now();
    let (mut reduced, adopted) = build_reduced(prob, e_set.clone(), opts);
    let mut t_solve = t0.elapsed().as_secs_f64();
    let mut widened = false;
    let mut inner_abs = 0.25 * gap_abs;
    // When a round ends gap-blocked with nothing to admit, the slack may
    // come from the reference bounds on the discarded coordinates rather
    // than from the inner solve — one forced full sweep settles which.
    let mut force_full = false;
    let mut loss;
    let mut gap;
    loop {
        // Cancellation between certificate rounds, mirroring the
        // safeguarded loop: round 1 always runs so `loss`/`gap` are
        // initialized, later rounds bail as soon as the token fires.
        if refits > 0 && opts.is_cancelled() {
            converged = false;
            break;
        }
        refits += 1;
        let t1 = Instant::now();
        let warm: Vec<f64> = reduced.coefs.iter().map(|&c| beta_full[c]).collect();
        // The inner solve carries both certificates: the same KKT
        // tolerance the safeguarded strategies demand (so gap-hybrid
        // solutions are interchangeable with strong-rule solutions) plus
        // the inner gap that drives the global certificate.
        let mut fista_cfg = opts.fista.clone();
        if fista_cfg.kkt_tol_abs.is_none() {
            fista_cfg.kkt_tol_abs = Some(kkt_thresh);
        }
        if fista_cfg.cancel.is_none() {
            fista_cfg.cancel = opts.cancel.clone();
        }
        fista_cfg.gap_tol_abs = Some(inner_abs);
        let res = solve(
            &reduced,
            &scale_prefix(lambda_base, sig, reduced.len()),
            Some(&warm),
            &fista_cfg,
        );
        solver_iterations += res.iterations;
        converged &= res.converged;
        loss = res.loss;
        reduced.scatter(&res.beta, beta_full);
        t_solve += t1.elapsed().as_secs_f64();

        // --- universe sweep + global gap ---------------------------------
        let t2 = Instant::now();
        eta.copy_from_slice(&res.eta);
        prob.family.h_loss(eta, &prob.y, h);
        if force_full || 2 * universe.len() > pt || !gs.screener.has_reference() {
            // A (near-)full universe sweep costs the same as a full one —
            // take the full product and refresh the sphere reference for
            // every later bound, for free.
            evaluator.full_grad_with(beta_full, h, grad, par);
            note_full_sweep(pt);
            sweeps += 1.0;
            gs.adopt_exact(h, grad, loss);
            force_full = false;
        } else {
            universe_gradient(
                prob,
                &universe,
                h,
                grad,
                par,
                &mut gs.scratch,
                &mut gs.cols,
                &mut gs.coefs,
            );
            obsreg::GRAD_PARTIAL_SWEEPS.inc();
            obsreg::GRAD_SWEEP_COLS.add(universe.len() as u64);
            sweeps += universe.len() as f64 / pt.max(1) as f64;
            let d = gs.screener.ref_distance(h);
            for j in 0..pt {
                gs.grad_bound[j] = gs.screener.mag_bound(j, d);
            }
            for &j in &universe {
                gs.grad_bound[j] = grad[j].abs();
            }
            gs.loss = loss;
            gs.grad_is_exact = false;
        }
        let penalty = crate::slope::sorted::sl1_norm(&res.beta, lam_cur);
        // One ordering per round, shared by the gap's feasibility
        // magnitudes and the violator selection below (the fused sweep).
        ws.rank(&gs.grad_bound);
        ws.ranked_magnitudes_into(&mut gs.mags);
        let gr = crate::slope::dual::duality_gap(
            prob.family,
            &prob.y,
            h,
            loss,
            penalty,
            &gs.mags,
            lam_cur,
        );
        gap = gr.gap;
        if !crate::obs::trace::disabled() {
            // Gap trajectory: one point event per certificate check, so a
            // trace replays how the working set converged within the step.
            crate::obs::trace::event(
                "gap_check",
                vec![
                    ("sigma", Json::Num(sig)),
                    ("round", Json::Num(refits as f64)),
                    ("gap", Json::Num(gap)),
                    ("gap_abs", Json::Num(gap_abs)),
                    ("n_fitted", Json::Num(e_set.len() as f64)),
                    ("n_universe", Json::Num(universe.len() as f64)),
                ],
            );
        }
        t_kkt += t2.elapsed().as_secs_f64();

        // Poisoned arithmetic (a NaN gradient, an overflowed loss): no
        // later round can certify from a non-finite gap — bail out
        // non-converged and let the degradation ladder retry the step
        // from its snapshot.
        if !gap.is_finite() {
            converged = false;
            break;
        }
        if gap <= gap_abs {
            break;
        }
        if refits >= MAX_GAP_ROUNDS {
            converged = false;
            break;
        }

        // --- expand by the top-K ranked violators / tighten ---------------
        // (reuses the ranking computed for the gap above — the flagger
        // reads it without consuming it)
        let t3 = Instant::now();
        let top_k = e_set.len().max(10);
        let viols: Vec<usize> = {
            let flagged = ws.kkt_flagged_in_rank_order(lam_cur, kkt_thresh);
            let mut picked: Vec<usize> = flagged
                .into_iter()
                .filter(|&j| contains_sorted(&universe, j) && !contains_sorted(&e_set, j))
                .take(top_k)
                .collect();
            picked.sort_unstable();
            picked
        };
        if viols.is_empty() {
            if !gs.grad_is_exact {
                // Nothing to admit and the gap was computed with bound
                // stand-ins: refresh the reference before concluding the
                // inner solve is the blocker.
                force_full = true;
            } else {
                // Exact gradient, no violator: the inner accuracy is the
                // blocker.
                inner_abs *= 0.25;
            }
        } else {
            added_by_kkt = union_sorted(&added_by_kkt, &viols);
            e_set = union_sorted(&e_set, &viols);
            reduced.append(&viols);
            widened = true;
        }
        // Shrink the universe with the fresh certificate: discards are
        // permanent for this σ, so every later sweep gets cheaper.
        if let Some(radius) = SafeScreener::radius(gap, prob.family.hessian_bound()) {
            if gs.screener.has_reference() {
                let kept: Vec<usize> = universe
                    .iter()
                    .copied()
                    .filter(|&j| gs.screener.keeps(gs.grad_bound[j], j, gr.scale, radius, lam_min))
                    .collect();
                universe = union_sorted(&kept, &e_set);
            }
        }
        t_solve += t3.elapsed().as_secs_f64();
    }
    gs.loss = loss;
    // Deposit the finished pack exactly like the safeguarded loop.
    if !adopted || widened {
        if let Some(cache) = &opts.pack_cache {
            if let Some(set) = reduced.packed_set() {
                cache.store(set);
            }
        }
    }
    SolveOutcome {
        loss,
        e_set,
        added_by_kkt,
        refits,
        solver_iterations,
        t_solve,
        t_kkt,
        converged,
        sweeps,
        n_universe: Some(universe.len()),
        gap: Some(gap),
    }
}

/// Predictors flagged as possibly active by Algorithm 1 on the true
/// gradient, with a small tolerance on the running sum (guards against
/// flagging predictors whose prefix sum is numerically ~0 — the
/// conservative corner case Prop. 1 describes).
///
/// Kept (hidden) as the frozen standalone reference for the fused
/// sweep's [`StrongWorkspace::kkt_flagged_ranked`], which the safeguard
/// loop uses so the KKT check shares its gradient ordering with the next
/// step's strong set; `kkt_flagged_ranked_matches_reference` pins the two
/// together.
#[doc(hidden)]
pub fn kkt_flagged(grad: &[f64], lam: &[f64], tol: f64) -> Vec<usize> {
    let ord = crate::linalg::ops::order_desc_abs(grad);
    let mut flagged = Vec::new();
    let mut block = Vec::new();
    let mut sum = 0.0f64;
    for (pos, &idx) in ord.iter().enumerate() {
        block.push(idx);
        sum += grad[idx].abs() - lam[pos];
        if sum >= tol {
            flagged.append(&mut block);
            sum = 0.0;
        }
    }
    flagged.sort_unstable();
    flagged
}

fn scale_prefix(lambda_base: &[f64], sigma: f64, len: usize) -> Vec<f64> {
    lambda_base[..len].iter().map(|l| l * sigma).collect()
}

/// Union of two ascending index sets.
pub fn union_sorted(a: &[usize], b: &[usize]) -> Vec<usize> {
    let mut out = Vec::with_capacity(a.len() + b.len());
    let (mut i, mut j) = (0, 0);
    while i < a.len() || j < b.len() {
        match (a.get(i), b.get(j)) {
            (Some(&x), Some(&y)) if x == y => {
                out.push(x);
                i += 1;
                j += 1;
            }
            (Some(&x), Some(&y)) if x < y => {
                out.push(x);
                i += 1;
            }
            (Some(_), Some(&y)) => {
                out.push(y);
                j += 1;
            }
            (Some(&x), None) => {
                out.push(x);
                i += 1;
            }
            (None, Some(&y)) => {
                out.push(y);
                j += 1;
            }
            (None, None) => unreachable!(),
        }
    }
    out
}

/// `a ∖ b` for ascending index sets.
pub fn diff_sorted(a: &[usize], b: &[usize]) -> Vec<usize> {
    let mut out = Vec::new();
    let mut j = 0;
    for &x in a {
        while j < b.len() && b[j] < x {
            j += 1;
        }
        if j >= b.len() || b[j] != x {
            out.push(x);
        }
    }
    out
}

/// `a ∩ b` for ascending index sets.
pub fn intersect_sorted(a: &[usize], b: &[usize]) -> Vec<usize> {
    let mut out = Vec::new();
    let mut j = 0;
    for &x in a {
        while j < b.len() && b[j] < x {
            j += 1;
        }
        if j < b.len() && b[j] == x {
            out.push(x);
        }
    }
    out
}

/// Cumulative screened-set efficiency of a fit: mean over steps of
/// `screened / max(active, 1)` (the paper's "efficiency" notion, §3.2.1).
pub fn mean_efficiency(fit: &PathFit) -> f64 {
    let vals: Vec<f64> = fit
        .steps
        .iter()
        .skip(1)
        .map(|s| s.n_screened_rule as f64 / s.n_active.max(1) as f64)
        .collect();
    crate::linalg::ops::mean(&vals)
}

/// Convenience: cumulative sums of per-step wall time per phase.
pub fn phase_totals(fit: &PathFit) -> (f64, f64, f64) {
    let mut t = (0.0, 0.0, 0.0);
    for s in &fit.steps {
        t.0 += s.t_screen;
        t.1 += s.t_solve;
        t.2 += s.t_kkt;
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{Design, Mat};
    use crate::rng::Pcg64;
    use crate::slope::lambda::LambdaKind;
    use crate::slope::subdiff::kkt_optimal;

    fn gaussian_problem(seed: u64, n: usize, p: usize, k: usize) -> Problem {
        let mut rng = Pcg64::new(seed);
        let mut x = Mat::zeros(n, p);
        for j in 0..p {
            for i in 0..n {
                x.set(i, j, rng.normal());
            }
        }
        x.standardize(true, true);
        let mut eta = vec![0.0; n];
        let beta: Vec<f64> = (0..p).map(|j| if j < k { 2.0 * rng.sign() } else { 0.0 }).collect();
        x.gemv(&beta, &mut eta);
        let y: Vec<f64> = eta.iter().map(|e| e + 0.5 * rng.normal()).collect();
        Problem::new(Design::Dense(x), y, Family::Gaussian)
    }

    fn opts(kind: LambdaKind, strategy: Strategy, len: usize) -> PathOptions {
        let mut cfg = PathConfig::new(kind);
        cfg.length = len;
        PathOptions::new(cfg).with_strategy(strategy)
    }

    #[test]
    fn first_step_is_zero_solution() {
        let prob = gaussian_problem(1, 30, 20, 3);
        let o = opts(LambdaKind::Bh { q: 0.1 }, Strategy::StrongSet, 10);
        let fit = fit_path(&prob, &o, &NativeGradient(&prob));
        assert_eq!(fit.steps[0].n_active, 0);
        assert!(fit.steps.last().unwrap().n_active > 0);
    }

    #[test]
    fn all_strategies_agree_on_solutions() {
        let prob = gaussian_problem(2, 40, 30, 4);
        let mk = |s| {
            let mut o = opts(LambdaKind::Bh { q: 0.1 }, s, 20);
            o.fista.tol = 1e-9;
            fit_path(&prob, &o, &NativeGradient(&prob))
        };
        let none = mk(Strategy::NoScreening);
        let strong = mk(Strategy::StrongSet);
        let prev = mk(Strategy::PreviousSet);
        let steps = none.steps.len().min(strong.steps.len()).min(prev.steps.len());
        assert!(steps >= 5);
        for m in 0..steps {
            let a = none.beta_at(m, prob.p_total());
            let b = strong.beta_at(m, prob.p_total());
            let c = prev.beta_at(m, prob.p_total());
            for i in 0..prob.p_total() {
                assert!(
                    (a[i] - b[i]).abs() < 1e-4,
                    "strong differs at step {m} coef {i}: {} vs {}",
                    a[i],
                    b[i]
                );
                assert!(
                    (a[i] - c[i]).abs() < 1e-4,
                    "previous differs at step {m} coef {i}: {} vs {}",
                    a[i],
                    c[i]
                );
            }
        }
    }

    #[test]
    fn solutions_satisfy_kkt_along_path() {
        let prob = gaussian_problem(3, 30, 25, 3);
        let mut o = opts(LambdaKind::Bh { q: 0.1 }, Strategy::StrongSet, 12);
        o.fista.tol = 1e-10;
        let fit = fit_path(&prob, &o, &NativeGradient(&prob));
        for (m, &sig) in fit.sigmas.iter().enumerate().skip(1) {
            let beta = fit.beta_at(m, prob.p_total());
            let (_, grad) = prob.loss_grad(&beta);
            let lam: Vec<f64> = fit.lambda_base.iter().map(|l| l * sig).collect();
            assert!(
                kkt_optimal(&beta, &grad, &lam, 1e-4 * sig * fit.lambda_base[0]),
                "step {m} fails KKT"
            );
        }
    }

    #[test]
    fn screened_set_smaller_than_full_for_p_gg_n() {
        let prob = gaussian_problem(4, 20, 200, 5);
        let o = opts(LambdaKind::Bh { q: 0.05 }, Strategy::StrongSet, 15);
        let fit = fit_path(&prob, &o, &NativeGradient(&prob));
        let sizes: Vec<usize> =
            fit.steps.iter().skip(1).map(|s| s.n_screened_rule).collect();
        // Screening is never vacuous (the whole point of the rule)...
        assert!(sizes.iter().all(|&s| s < prob.p()), "vacuous screening: {sizes:?}");
        // ...and is strongly selective early in the path, where the paper
        // reports its largest wins (Figs. 1–2).
        assert!(sizes[0] < prob.p() / 2, "weak early screening: {sizes:?}");
    }

    #[test]
    fn screened_set_contains_active_set() {
        // The safeguarded fit must end each step with E ⊇ active set.
        let prob = gaussian_problem(5, 25, 80, 4);
        let o = opts(LambdaKind::Bh { q: 0.1 }, Strategy::StrongSet, 15);
        let fit = fit_path(&prob, &o, &NativeGradient(&prob));
        for s in fit.steps.iter().skip(1) {
            assert!(s.n_fitted >= s.n_active);
        }
    }

    #[test]
    fn lasso_sequence_matches_lasso_screening() {
        // With constant λ the strong rule reduces to the lasso rule
        // (Prop. 3) and the path still solves to optimality.
        let prob = gaussian_problem(6, 30, 40, 3);
        let o = opts(LambdaKind::Lasso, Strategy::StrongSet, 10);
        let fit = fit_path(&prob, &o, &NativeGradient(&prob));
        assert!(fit.steps.last().unwrap().n_active > 0);
    }

    #[test]
    fn early_stop_dev_ratio_fires_for_easy_problem() {
        // Strong signal, tiny noise: deviance ratio crosses 0.995 quickly.
        let mut rng = Pcg64::new(7);
        let n = 100;
        let p = 10;
        let mut x = Mat::zeros(n, p);
        for j in 0..p {
            for i in 0..n {
                x.set(i, j, rng.normal());
            }
        }
        x.standardize(true, true);
        let beta: Vec<f64> = (0..p).map(|j| if j < 3 { 5.0 } else { 0.0 }).collect();
        let mut eta = vec![0.0; n];
        x.gemv(&beta, &mut eta);
        let y: Vec<f64> = eta.iter().map(|e| e + 1e-4 * rng.normal()).collect();
        let prob = Problem::new(Design::Dense(x), y, Family::Gaussian);
        let o = opts(LambdaKind::Bh { q: 0.1 }, Strategy::StrongSet, 100);
        let fit = fit_path(&prob, &o, &NativeGradient(&prob));
        assert!(fit.stopped_early.is_some());
        assert!(fit.steps.len() < 100);
    }

    #[test]
    fn set_algebra_helpers() {
        assert_eq!(union_sorted(&[1, 3, 5], &[2, 3, 6]), vec![1, 2, 3, 5, 6]);
        assert_eq!(diff_sorted(&[1, 2, 3, 4], &[2, 4]), vec![1, 3]);
        assert_eq!(intersect_sorted(&[1, 2, 3], &[2, 3, 9]), vec![2, 3]);
        assert_eq!(union_sorted(&[], &[]), Vec::<usize>::new());
        assert_eq!(diff_sorted(&[], &[1]), Vec::<usize>::new());
    }

    #[test]
    fn logistic_path_runs() {
        let mut rng = Pcg64::new(8);
        let n = 40;
        let p = 60;
        let mut x = Mat::zeros(n, p);
        for j in 0..p {
            for i in 0..n {
                x.set(i, j, rng.normal());
            }
        }
        x.standardize(true, true);
        let mut eta = vec![0.0; n];
        let beta: Vec<f64> = (0..p).map(|j| if j < 3 { 3.0 } else { 0.0 }).collect();
        x.gemv(&beta, &mut eta);
        let y: Vec<f64> = eta
            .iter()
            .map(|&e| if rng.bernoulli(crate::slope::family::sigmoid(4.0 * e)) { 1.0 } else { 0.0 })
            .collect();
        let prob = Problem::new(Design::Dense(x), y, Family::Binomial);
        let o = opts(LambdaKind::Bh { q: 0.1 }, Strategy::StrongSet, 15);
        let fit = fit_path(&prob, &o, &NativeGradient(&prob));
        assert!(fit.steps.last().unwrap().n_active > 0);
        assert!(fit.steps.iter().all(|s| s.dev_ratio >= -1e-9));
    }

    #[test]
    fn multinomial_path_runs() {
        let mut rng = Pcg64::new(9);
        let n = 45;
        let p = 12;
        let mut x = Mat::zeros(n, p);
        for j in 0..p {
            for i in 0..n {
                x.set(i, j, rng.normal());
            }
        }
        x.standardize(true, true);
        let y: Vec<f64> = (0..n).map(|i| (i % 3) as f64).collect();
        let prob = Problem::new(Design::Dense(x), y, Family::Multinomial { classes: 3 });
        let o = opts(LambdaKind::Bh { q: 0.2 }, Strategy::StrongSet, 10);
        let fit = fit_path(&prob, &o, &NativeGradient(&prob));
        assert_eq!(fit.lambda_base.len(), p * 3);
        assert!(!fit.steps.is_empty());
    }

    #[test]
    fn zero_seed_matches_sigma_max() {
        let prob = gaussian_problem(10, 30, 40, 4);
        let o = opts(LambdaKind::Bh { q: 0.1 }, Strategy::StrongSet, 12);
        let fit = fit_path(&prob, &o, &NativeGradient(&prob));
        let zero = zero_seed(&prob, &o, &NativeGradient(&prob));
        assert!((zero.sigma - fit.sigmas[0]).abs() < 1e-12 * zero.sigma.max(1.0));
        assert!(zero.beta.iter().all(|&b| b == 0.0));
        assert_eq!(zero.grad.len(), prob.p_total());
    }

    #[test]
    fn fit_point_matches_path_step() {
        let prob = gaussian_problem(10, 30, 40, 4);
        let mut o = opts(LambdaKind::Bh { q: 0.1 }, Strategy::StrongSet, 12);
        o.fista.tol = 1e-9;
        let fit = fit_path(&prob, &o, &NativeGradient(&prob));
        let zero = zero_seed(&prob, &o, &NativeGradient(&prob));
        let m = 5.min(fit.sigmas.len() - 1);
        let point = fit_point(&prob, &o, &NativeGradient(&prob), fit.sigmas[m], &zero);
        let want = fit.beta_at(m, prob.p_total());
        for i in 0..prob.p_total() {
            assert!(
                (point.beta[i] - want[i]).abs() < 1e-4,
                "coef {i}: point {} vs path {}",
                point.beta[i],
                want[i]
            );
        }
        assert!(point.n_fitted >= point.n_active);
    }

    #[test]
    fn fit_point_batch_bitwise_matches_sequential_chain() {
        let prob = gaussian_problem(12, 30, 50, 4);
        let ng = NativeGradient(&prob);
        let cold = opts(LambdaKind::Bh { q: 0.1 }, Strategy::StrongSet, 10);
        let warm = PathOptions { strategy: Strategy::PreviousSet, ..cold.clone() };
        let zero = zero_seed(&prob, &cold, &ng);
        let sigmas = [zero.sigma * 0.6, zero.sigma * 0.4, zero.sigma * 0.45, zero.sigma * 0.3];
        // Chained batch vs the literal store/read sequence a cache-enabled
        // server would run: must be bitwise identical per item.
        let batch = fit_point_batch(&prob, &cold, &warm, &ng, &zero, &sigmas, true);
        let mut seed = zero.clone();
        for (k, &sigma) in sigmas.iter().enumerate() {
            let o = if k == 0 { &cold } else { &warm };
            let want = fit_point(&prob, o, &ng, sigma, &seed);
            assert_eq!(batch[k].violations, want.violations, "item {k} violations");
            assert_eq!(batch[k].n_fitted, want.n_fitted, "item {k} n_fitted");
            for (i, (a, b)) in batch[k].beta.iter().zip(&want.beta).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "item {k} coef {i}");
            }
            seed = want.seed();
        }
        // Unchained batch vs independent cold requests from the shared seed.
        let batch = fit_point_batch(&prob, &cold, &warm, &ng, &zero, &sigmas, false);
        for (k, &sigma) in sigmas.iter().enumerate() {
            let want = fit_point(&prob, &cold, &ng, sigma, &zero);
            for (a, b) in batch[k].beta.iter().zip(&want.beta) {
                assert_eq!(a.to_bits(), b.to_bits(), "unchained item {k}");
            }
        }
    }

    #[test]
    fn fit_point_warm_seed_reuses_state() {
        let prob = gaussian_problem(11, 30, 60, 4);
        let o = opts(LambdaKind::Bh { q: 0.1 }, Strategy::StrongSet, 10);
        let ng = NativeGradient(&prob);
        let zero = zero_seed(&prob, &o, &ng);
        let sigma = zero.sigma * 0.5;
        let cold = fit_point(&prob, &o, &ng, sigma, &zero);
        // Re-solving at the same σ from the returned seed starts at the
        // optimum: same solution, no more iterations than the cold solve.
        let warm = fit_point(&prob, &o, &ng, sigma, &cold.seed());
        assert!(warm.solver_iterations <= cold.solver_iterations);
        for (a, b) in warm.beta.iter().zip(&cold.beta) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn seeded_path_matches_cold_path() {
        let prob = gaussian_problem(12, 35, 50, 4);
        let o = opts(LambdaKind::Bh { q: 0.1 }, Strategy::StrongSet, 12);
        let ng = NativeGradient(&prob);
        let cold = fit_path(&prob, &o, &ng);
        let warm = fit_path_seeded(&prob, &o, &ng, Some(&cold.seed()));
        let steps = cold.sigmas.len().min(warm.sigmas.len());
        assert!(steps >= 2);
        for m in 0..steps {
            let a = cold.beta_at(m, prob.p_total());
            let b = warm.beta_at(m, prob.p_total());
            for i in 0..prob.p_total() {
                assert!((a[i] - b[i]).abs() < 1e-4, "step {m} coef {i}");
            }
        }
        assert_eq!(warm.final_grad.len(), prob.p_total());
    }

    #[test]
    fn packed_engine_matches_gather_engine_exactly() {
        // The tentpole's correctness contract: on dense designs the
        // packed engine is bitwise interchangeable with the gather
        // engine — identical grids, violation counts, and coefficients.
        for strategy in [Strategy::StrongSet, Strategy::PreviousSet, Strategy::NoScreening] {
            let prob = gaussian_problem(20, 30, 80, 5);
            let gather = {
                let o = opts(LambdaKind::Bh { q: 0.1 }, strategy, 15).with_packing(false);
                fit_path(&prob, &o, &NativeGradient(&prob))
            };
            let packed = {
                let o = opts(LambdaKind::Bh { q: 0.1 }, strategy, 15).with_packing(true);
                fit_path(&prob, &o, &NativeGradient(&prob))
            };
            assert_eq!(gather.sigmas.len(), packed.sigmas.len(), "{}", strategy.name());
            assert_eq!(
                gather.total_violations, packed.total_violations,
                "{}: violation counts diverged",
                strategy.name()
            );
            for (a, b) in gather.steps.iter().zip(&packed.steps) {
                assert_eq!(a.violations, b.violations, "{}", strategy.name());
                assert_eq!(a.n_fitted, b.n_fitted, "{}", strategy.name());
                assert_eq!(a.solver_iterations, b.solver_iterations, "{}", strategy.name());
            }
            assert_eq!(
                gather.final_beta,
                packed.final_beta,
                "{}: coefficients diverged",
                strategy.name()
            );
            assert_eq!(gather.final_grad, packed.final_grad, "{}", strategy.name());
        }
    }

    #[test]
    fn packed_engine_matches_gather_engine_sparse_to_tolerance() {
        // Sparse designs are the one place the engines round differently
        // (the packed slab streams structural zeros the sparse gather
        // kernels skip), so the agreement contract is solver-level, not
        // bitwise: same grid, solutions within solver tolerance. Density
        // 0.4 keeps the design above the packing_profitable threshold so
        // the packed engine genuinely engages.
        use crate::linalg::Csc;
        let mut rng = Pcg64::new(22);
        let mut dense = Mat::zeros(40, 90);
        for j in 0..90 {
            for i in 0..40 {
                if rng.bernoulli(0.4) {
                    dense.set(i, j, rng.normal());
                }
            }
        }
        let mut eta = vec![0.0; 40];
        let beta: Vec<f64> = (0..90).map(|j| if j < 4 { 2.0 * rng.sign() } else { 0.0 }).collect();
        dense.gemv(&beta, &mut eta);
        let y: Vec<f64> = eta.iter().map(|e| e + 0.3 * rng.normal()).collect();
        let mut x = Design::Sparse(Csc::from_dense(&dense));
        x.standardize();
        let prob = Problem::new(x, y, Family::Gaussian);
        let mk = |packing: bool| {
            let mut o = opts(LambdaKind::Bh { q: 0.1 }, Strategy::StrongSet, 12).with_packing(packing);
            o.fista.tol = 1e-9;
            fit_path(&prob, &o, &NativeGradient(&prob))
        };
        let gather = mk(false);
        let packed = mk(true);
        let steps = gather.sigmas.len().min(packed.sigmas.len());
        assert!(steps >= 5);
        for m in 0..steps {
            let a = gather.beta_at(m, prob.p_total());
            let b = packed.beta_at(m, prob.p_total());
            for i in 0..prob.p_total() {
                assert!(
                    (a[i] - b[i]).abs() < 1e-5,
                    "sparse engines diverged at step {m} coef {i}: {} vs {}",
                    a[i],
                    b[i]
                );
            }
        }
    }

    #[test]
    fn too_sparse_designs_stay_on_gather_even_when_packing_requested() {
        // A dorothea-like low-density design must not be densified: the
        // density gate keeps it on the sparse gather kernels, observable
        // as an attached pack cache that never receives a deposit.
        use crate::linalg::packed::PackCache;
        use crate::linalg::Csc;
        let mut rng = Pcg64::new(23);
        let mut dense = Mat::zeros(50, 120);
        for j in 0..120 {
            for i in 0..50 {
                if rng.bernoulli(0.05) {
                    dense.set(i, j, rng.normal() + 1.0);
                }
            }
        }
        let y: Vec<f64> = (0..50).map(|_| rng.normal()).collect();
        let mut x = Design::Sparse(Csc::from_dense(&dense));
        x.standardize();
        let prob = Problem::new(x, y, Family::Gaussian);
        let cache = Arc::new(PackCache::new(64));
        let o = opts(LambdaKind::Bh { q: 0.1 }, Strategy::StrongSet, 8)
            .with_pack_cache(Arc::clone(&cache));
        let fit = fit_path(&prob, &o, &NativeGradient(&prob));
        assert!(!fit.steps.is_empty());
        assert!(cache.is_empty(), "a 5%-dense design must not be packed");
    }

    #[test]
    fn pack_cache_turns_repacks_into_hits() {
        use crate::linalg::packed::PackCache;
        let prob = gaussian_problem(21, 30, 60, 4);
        let cache = Arc::new(PackCache::new(64));
        let o = opts(LambdaKind::Bh { q: 0.1 }, Strategy::StrongSet, 10)
            .with_pack_cache(Arc::clone(&cache));
        let first = fit_path(&prob, &o, &NativeGradient(&prob));
        assert!(!cache.is_empty(), "a fit must deposit packs");
        let (hits_first, _) = cache.stats();
        // the identical fit repeats the same screened sets, so packing is
        // replaced by cache adoption — and adoption is bitwise invisible
        let again = fit_path(&prob, &o, &NativeGradient(&prob));
        let (hits_again, _) = cache.stats();
        assert!(
            hits_again > hits_first,
            "repeat fit must adopt cached packs (hits {hits_first} -> {hits_again})"
        );
        assert_eq!(first.sigmas.len(), again.sigmas.len());
        assert_eq!(first.final_beta, again.final_beta);
        // and an uncached but otherwise identical fit agrees too
        let plain = fit_path(
            &prob,
            &opts(LambdaKind::Bh { q: 0.1 }, Strategy::StrongSet, 10),
            &NativeGradient(&prob),
        );
        assert_eq!(plain.final_beta, first.final_beta);
    }

    #[test]
    fn gap_hybrid_matches_strong_baseline() {
        // The tentpole's correctness contract at test scale: gap-certified
        // hybrid (and safe-only) fits walk the same grid as the strong
        // baseline with the same violation counts and matching
        // coefficients.
        let prob = gaussian_problem(30, 40, 60, 4);
        let mk = |s| {
            let mut o = opts(LambdaKind::Bh { q: 0.1 }, s, 15);
            o.fista.tol = 1e-9;
            fit_path(&prob, &o, &NativeGradient(&prob))
        };
        let strong = mk(Strategy::StrongSet);
        for alt in [Strategy::GapHybrid, Strategy::SafeOnly] {
            let fit = mk(alt);
            assert!(fit.sigmas.len() >= 5, "{}", alt.name());
            for (m, s) in fit.steps.iter().enumerate() {
                assert!(s.solver_converged, "{} step {m} not converged", alt.name());
                if m > 0 {
                    let gap = s.gap.expect("gap-driven steps record their certificate");
                    assert!(gap.is_finite(), "{} step {m} gap {gap}", alt.name());
                    let nu = s.n_universe.expect("gap-driven steps record the universe");
                    assert!(nu <= prob.p_total());
                    assert!(s.n_fitted <= nu, "{}: fitted set outside universe", alt.name());
                    assert!(s.full_grad_sweeps > 0.0);
                }
            }
            let steps = fit.sigmas.len().min(strong.sigmas.len());
            for m in 0..steps {
                let a = fit.beta_at(m, prob.p_total());
                let b = strong.beta_at(m, prob.p_total());
                for i in 0..prob.p_total() {
                    assert!(
                        (a[i] - b[i]).abs() < 1e-5,
                        "{} step {m} coef {i}: {} vs {}",
                        alt.name(),
                        a[i],
                        b[i]
                    );
                }
            }
            // Safe-only admits the whole certified universe: violations
            // are impossible by construction.
            if alt == Strategy::SafeOnly {
                assert_eq!(fit.total_violations, 0, "safe-only cannot violate");
            }
            assert!(fit.total_grad_sweeps > 0.0);
            // final_grad must be exact — the warm-seed contract
            let (_, g) = prob.loss_grad(&fit.final_beta);
            for (a, b) in fit.final_grad.iter().zip(&g) {
                assert!((a - b).abs() < 1e-8, "final_grad not exact: {a} vs {b}");
            }
        }
    }

    #[test]
    fn gap_hybrid_fit_point_matches_strong_fit_point() {
        let prob = gaussian_problem(31, 30, 50, 4);
        let mut o_strong = opts(LambdaKind::Bh { q: 0.1 }, Strategy::StrongSet, 10);
        o_strong.fista.tol = 1e-9;
        let o_hybrid = o_strong.clone().with_strategy(Strategy::GapHybrid);
        let ng = NativeGradient(&prob);
        let zero = zero_seed(&prob, &o_strong, &ng);
        let sigma = zero.sigma * 0.4;
        let a = fit_point(&prob, &o_strong, &ng, sigma, &zero);
        let b = fit_point(&prob, &o_hybrid, &ng, sigma, &zero);
        for (x, y) in a.beta.iter().zip(&b.beta) {
            assert!((x - y).abs() < 1e-5, "{x} vs {y}");
        }
        assert!(b.solver_converged);
        assert!(b.gap.is_some());
        assert!(b.full_grad_sweeps > 0.0);
        // the hybrid point's returned gradient is exact (next-seed contract)
        let (_, g) = prob.loss_grad(&b.beta);
        for (x, y) in b.grad.iter().zip(&g) {
            assert!((x - y).abs() < 1e-8);
        }
        // warm re-solve from the hybrid seed sees per-request safe
        // screening and still agrees
        let warm = fit_point(&prob, &o_hybrid, &ng, sigma, &b.seed());
        for (x, y) in warm.beta.iter().zip(&b.beta) {
            assert!((x - y).abs() < 1e-6);
        }
    }

    #[test]
    fn gap_hybrid_seeded_path_matches_cold_and_sweeps_do_not_grow() {
        let prob = gaussian_problem(32, 30, 80, 4);
        let o = opts(LambdaKind::Bh { q: 0.1 }, Strategy::GapHybrid, 12);
        let ng = NativeGradient(&prob);
        let cold = fit_path(&prob, &o, &ng);
        let warm = fit_path_seeded(&prob, &o, &ng, Some(&cold.seed()));
        let steps = cold.sigmas.len().min(warm.sigmas.len());
        for m in 0..steps {
            let a = cold.beta_at(m, prob.p_total());
            let b = warm.beta_at(m, prob.p_total());
            for i in 0..prob.p_total() {
                assert!((a[i] - b[i]).abs() < 1e-4, "step {m} coef {i}");
            }
        }
        // sweep accounting sanity: bounded by rounds, never runaway
        let round_total: usize = warm.steps.iter().map(|s| s.refits).sum();
        let bound = (round_total + warm.steps.len()) as f64 + 2.0;
        assert!(
            warm.total_grad_sweeps <= bound,
            "warm sweeps {} exceed {bound}",
            warm.total_grad_sweeps
        );
    }

    #[test]
    fn gap_hybrid_matches_strong_for_glm_families() {
        // universe_gradient's class partition, the entropy dual
        // objectives and the partial-universe sweeps must hold beyond the
        // Gaussian family — binomial (n·1 residual blocks), multinomial
        // (class-major blocks) and Poisson (no curvature bound: hybrid
        // degrades to gap-certified full sweeps) each walk the full
        // gap-driven loop and must agree with the strong baseline.
        let mut rng = Pcg64::new(40);
        let n = 50;
        let p = 16;
        let mut x = Mat::zeros(n, p);
        for j in 0..p {
            for i in 0..n {
                x.set(i, j, rng.normal());
            }
        }
        x.standardize(true, true);
        let mut eta = vec![0.0; n];
        let beta: Vec<f64> = (0..p).map(|j| if j < 3 { 1.5 } else { 0.0 }).collect();
        x.gemv(&beta, &mut eta);
        let cases: Vec<(Family, Vec<f64>)> = vec![
            (
                Family::Binomial,
                eta.iter()
                    .map(|&e| {
                        if rng.bernoulli(crate::slope::family::sigmoid(e)) {
                            1.0
                        } else {
                            0.0
                        }
                    })
                    .collect(),
            ),
            (
                Family::Multinomial { classes: 3 },
                (0..n).map(|i| (i % 3) as f64).collect(),
            ),
            (
                Family::Poisson,
                eta.iter()
                    .map(|&e| rng.poisson(e.clamp(-2.0, 2.0).exp()) as f64)
                    .collect(),
            ),
        ];
        for (family, y) in cases {
            let prob = Problem::new(Design::Dense(x.clone()), y, family);
            let mk = |s| {
                let mut o = opts(LambdaKind::Bh { q: 0.1 }, s, 10);
                o.fista.tol = 1e-9;
                // headroom for the slower-converging entropy losses
                o.fista.max_iter = 30_000;
                fit_path(&prob, &o, &NativeGradient(&prob))
            };
            let strong = mk(Strategy::StrongSet);
            let hybrid = mk(Strategy::GapHybrid);
            let steps = strong.sigmas.len().min(hybrid.sigmas.len());
            assert!(steps >= 2, "{}", family.name());
            for m in 0..steps {
                let a = strong.beta_at(m, prob.p_total());
                let b = hybrid.beta_at(m, prob.p_total());
                for i in 0..prob.p_total() {
                    assert!(
                        (a[i] - b[i]).abs() < 1e-4,
                        "{} step {m} coef {i}: {} vs {}",
                        family.name(),
                        a[i],
                        b[i]
                    );
                }
            }
            for (m, s) in hybrid.steps.iter().enumerate().skip(1) {
                assert!(s.solver_converged, "{} step {m}", family.name());
                assert!(s.gap.is_some(), "{} step {m}", family.name());
            }
        }
    }

    #[test]
    fn nonconverged_inner_solve_is_surfaced_not_hidden() {
        // max_iter too small to certify: the step must report
        // solver_converged = false instead of letting solver noise pose
        // as screening-rule violations.
        let prob = gaussian_problem(33, 30, 40, 4);
        for strategy in [Strategy::StrongSet, Strategy::GapHybrid] {
            let mut o = opts(LambdaKind::Bh { q: 0.1 }, strategy, 6);
            o.fista.max_iter = 2;
            o.fista.tol = 1e-14;
            let fit = fit_path(&prob, &o, &NativeGradient(&prob));
            assert!(
                fit.steps.iter().skip(1).any(|s| !s.solver_converged),
                "{}: starved solver must surface non-convergence",
                strategy.name()
            );
        }
    }

    #[test]
    fn shared_col_norms_do_not_change_hybrid_fits() {
        // The serve registry hands fits a cached per-dataset norm vector;
        // it must be a pure performance transformation (dense column
        // norms are bitwise-deterministic across thread counts).
        let prob = gaussian_problem(35, 30, 40, 3);
        let o = opts(LambdaKind::Bh { q: 0.1 }, Strategy::GapHybrid, 10);
        let norms: Arc<Vec<f64>> = Arc::new(prob.x.col_norms_with(ParConfig::serial()));
        let with = fit_path(
            &prob,
            &o.clone().with_col_norms(Arc::clone(&norms)),
            &NativeGradient(&prob),
        );
        let without = fit_path(&prob, &o, &NativeGradient(&prob));
        assert_eq!(with.final_beta, without.final_beta);
        assert_eq!(with.total_grad_sweeps, without.total_grad_sweeps);
        // wrong-length norms are refused, not trusted
        let bad = o.with_col_norms(Arc::new(vec![1.0; 3]));
        let guarded = fit_path(&prob, &bad, &NativeGradient(&prob));
        assert_eq!(guarded.final_beta, without.final_beta);
    }

    #[test]
    fn strategy_names_and_gap_driven_split() {
        assert_eq!(Strategy::SafeOnly.name(), "safe");
        assert_eq!(Strategy::GapHybrid.name(), "hybrid");
        assert!(Strategy::SafeOnly.is_gap_driven());
        assert!(Strategy::GapHybrid.is_gap_driven());
        assert!(!Strategy::StrongSet.is_gap_driven());
        assert!(!Strategy::PreviousSet.is_gap_driven());
        assert!(!Strategy::NoScreening.is_gap_driven());
    }

    #[test]
    fn sweep_accounting_matches_step_records() {
        let prob = gaussian_problem(34, 25, 30, 3);
        for strategy in [Strategy::StrongSet, Strategy::GapHybrid] {
            let o = opts(LambdaKind::Bh { q: 0.1 }, strategy, 8);
            let fit = fit_path(&prob, &o, &NativeGradient(&prob));
            let step_sum: f64 = fit.steps.iter().map(|s| s.full_grad_sweeps).sum();
            // totals equal the per-step sum, plus at most the one closing
            // refresh a gap-driven fit may pay
            assert!(
                fit.total_grad_sweeps >= step_sum - 1e-9
                    && fit.total_grad_sweeps <= step_sum + 1.0 + 1e-9,
                "{}: total {} vs step sum {step_sum}",
                strategy.name(),
                fit.total_grad_sweeps
            );
            // baseline strategies pay exactly one full sweep per refit round
            if strategy == Strategy::StrongSet {
                for s in fit.steps.iter().skip(1) {
                    assert!((s.full_grad_sweeps - s.refits as f64).abs() < 1e-12);
                }
            }
        }
    }

    #[test]
    fn kkt_flagged_ranked_matches_reference() {
        use crate::check::{ensure, forall, gen, Config};
        forall(
            Config { cases: 300, seed: 0x2f1 },
            |rng| {
                let g = gen::normal_vec(rng, 1, 40);
                let lam = gen::lambda_seq(rng, g.len());
                (g, lam)
            },
            |(g, lam)| {
                let mut ws = StrongWorkspace::default();
                ws.rank(g);
                let ranked = ws.kkt_flagged_ranked(lam, 1e-9);
                let reference = kkt_flagged(g, lam, 1e-9);
                ensure(ranked == reference, format!("{ranked:?} vs {reference:?}"))
            },
        );
    }

    #[test]
    fn cumsum_sanity_for_flagging() {
        // kkt_flagged flags exactly the prefix whose running sum crosses 0.
        let grad = [2.0, 0.1, 0.05];
        let lam = [1.0, 0.9, 0.8];
        let flagged = kkt_flagged(&grad, &lam, 1e-12);
        assert_eq!(flagged, vec![0]);
        let none = kkt_flagged(&[0.5, 0.1, 0.05], &lam, 1e-12);
        assert!(none.is_empty());
    }

    #[test]
    fn gap_stall_is_rescued_by_the_degradation_ladder() {
        // A gap tolerance below the numeric floor makes every hybrid
        // step stall at MAX_GAP_ROUNDS; the ladder must rescue each one
        // under the strong strategy and report both the rescue and a
        // *converged* fit — never a silently non-converged one.
        let prob = gaussian_problem(44, 30, 40, 4);
        let mut o = opts(LambdaKind::Bh { q: 0.1 }, Strategy::GapHybrid, 5);
        o.fista.max_iter = 2_000; // ample for the rescue, cheap for the doomed rounds
        o.gap_tol = f64::MIN_POSITIVE; // unreachable certificate
        let before = obsreg::PATH_DEGRADED_STEPS.get();
        let fit = fit_path(&prob, &o, &NativeGradient(&prob));
        for (m, s) in fit.steps.iter().enumerate().skip(1) {
            assert_eq!(s.degraded_to, Some("strong"), "step {m} not rescued");
            assert!(s.solver_converged, "rescued step {m} must converge");
        }
        assert!(
            obsreg::PATH_DEGRADED_STEPS.get() >= before + (fit.steps.len() - 1) as u64,
            "every rescued step must be counted"
        );
        // The rescued fit solves the same problems the strong strategy
        // solves directly — solutions must agree to solver tolerance.
        let strong = fit_path(
            &prob,
            &opts(LambdaKind::Bh { q: 0.1 }, Strategy::StrongSet, 5),
            &NativeGradient(&prob),
        );
        let steps = fit.steps.len().min(strong.steps.len());
        for m in 0..steps {
            let a = fit.beta_at(m, prob.p_total());
            let b = strong.beta_at(m, prob.p_total());
            for i in 0..prob.p_total() {
                assert!((a[i] - b[i]).abs() < 1e-4, "step {m} coef {i}: {} vs {}", a[i], b[i]);
            }
        }
    }

    #[test]
    fn ladder_off_surfaces_the_stall() {
        let prob = gaussian_problem(44, 30, 40, 4);
        let mut o = opts(LambdaKind::Bh { q: 0.1 }, Strategy::GapHybrid, 5);
        o.fista.max_iter = 2_000;
        o.gap_tol = f64::MIN_POSITIVE;
        o.degrade = false;
        let fit = fit_path(&prob, &o, &NativeGradient(&prob));
        assert!(fit.steps.iter().skip(1).all(|s| !s.solver_converged));
        assert!(fit.steps.iter().all(|s| s.degraded_to.is_none()));
    }

    #[test]
    fn pre_fired_token_stops_the_path_at_the_bootstrap() {
        let prob = gaussian_problem(45, 30, 40, 4);
        let tok = CancelToken::new();
        tok.cancel();
        let mut o = opts(LambdaKind::Bh { q: 0.1 }, Strategy::StrongSet, 10);
        o.cancel = Some(tok);
        let fit = fit_path(&prob, &o, &NativeGradient(&prob));
        assert_eq!(fit.stopped_early, Some("cancelled"));
        assert_eq!(fit.steps.len(), 1, "only the β = 0 bootstrap step runs");
        // Partial state keeps the warm-start contract: β and ∇f(β) agree.
        assert_eq!(fit.final_beta.len(), prob.p_total());
        assert_eq!(fit.final_grad.len(), prob.p_total());
    }

    #[test]
    fn unfired_token_is_bitwise_invisible() {
        // The zero-cost-when-healthy contract at the unit level: a token
        // that never fires must not perturb a single bit of the fit.
        let prob = gaussian_problem(46, 40, 60, 5);
        for strategy in [Strategy::StrongSet, Strategy::GapHybrid] {
            let o = opts(LambdaKind::Bh { q: 0.1 }, strategy, 10);
            let plain = fit_path(&prob, &o, &NativeGradient(&prob));
            let mut o_tok = opts(LambdaKind::Bh { q: 0.1 }, strategy, 10);
            o_tok.cancel = Some(CancelToken::with_deadline_ms(3_600_000));
            let tokened = fit_path(&prob, &o_tok, &NativeGradient(&prob));
            assert_eq!(plain.steps.len(), tokened.steps.len());
            for (a, b) in plain.final_beta.iter().zip(&tokened.final_beta) {
                assert_eq!(a.to_bits(), b.to_bits(), "{}: beta drift", strategy.name());
            }
            for (a, b) in plain.final_grad.iter().zip(&tokened.final_grad) {
                assert_eq!(a.to_bits(), b.to_bits(), "{}: grad drift", strategy.name());
            }
        }
    }

    #[test]
    fn fit_point_rescues_a_gap_stall() {
        let prob = gaussian_problem(47, 30, 40, 4);
        let mut o = opts(LambdaKind::Bh { q: 0.1 }, Strategy::GapHybrid, 5);
        o.fista.max_iter = 2_000;
        o.gap_tol = f64::MIN_POSITIVE;
        let seed = zero_seed(&prob, &o, &NativeGradient(&prob));
        let point = fit_point(&prob, &o, &NativeGradient(&prob), 0.5 * seed.sigma, &seed);
        assert!(point.solver_converged);
        assert_eq!(point.degraded_to, Some("strong"));
        // The rescue's closing sweep keeps the seed contract: the
        // returned gradient is the true full gradient at the solution.
        let (_, grad) = prob.loss_grad(&point.beta);
        for (a, b) in grad.iter().zip(&point.grad) {
            assert!((a - b).abs() < 1e-10, "seed gradient drift: {a} vs {b}");
        }
    }
}

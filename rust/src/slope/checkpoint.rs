//! Durable path-fit state: crash-safe snapshots of regularization-path
//! progress (DESIGN.md §13).
//!
//! A [`Snapshot`] captures everything the σ-loop of
//! [`crate::slope::path::fit_path_seeded`] holds at a step boundary —
//! solution, gradient, linear predictor, working residual, the per-step
//! records accumulated so far, and (for the gap-driven strategies) the
//! sphere-test reference state — so a killed fit can re-enter the loop at
//! the next σ index and continue **bitwise identically** to an
//! uninterrupted run. The contract has three layers:
//!
//! 1. **Atomic writes.** A snapshot is serialized to `<path>.tmp`,
//!    fsynced, and renamed over `<path>`; the previous good snapshot is
//!    kept at `<path>.prev` first. A crash mid-write can therefore tear
//!    only the temp file — `<path>` always holds a complete snapshot,
//!    and `<path>.prev` one more behind it.
//! 2. **Integrity.** The payload is length-prefixed and carries a
//!    trailing FNV-1a 64 digest; magic and version lead the file. A
//!    short file, a flipped bit, or a snapshot from a future format
//!    version each decode to a typed [`CheckpointError`] — never a
//!    panic, never a silently wrong resume.
//! 3. **Identity.** The snapshot embeds the dataset content fingerprint
//!    from ingest (or the synthetic spec's canonical fingerprint, which
//!    includes the RNG seed), a problem fingerprint over the response
//!    bits and shapes (covering the standardized `ColumnStats`
//!    coordinates the response was produced in), and a grid fingerprint
//!    over the λ sequence and σ grid. Resume validates the whole chain;
//!    a checkpoint can never be replayed against the wrong data, the
//!    wrong grid, or the wrong strategy.
//!
//! Floating-point payloads are encoded as IEEE-754 bit patterns
//! (`to_bits`), not decimal text: the resume contract is `to_bits`
//! equality, so the serialization must be exact by construction.

use std::fs;
use std::io::Write as _;
use std::path::{Path, PathBuf};

use crate::ingest::{fnv1a, FNV_BASIS};
use crate::obs::registry as obsreg;
use crate::slope::family::Problem;

/// Leading magic bytes of every checkpoint file.
pub const MAGIC: [u8; 8] = *b"SLPCKPT1";

/// Current snapshot format version. Bump on any layout change; readers
/// reject anything newer with [`CheckpointError::FutureVersion`].
pub const VERSION: u32 = 1;

/// A typed checkpoint failure. Every corrupt, torn, stale or
/// future-format snapshot maps to one of these — the resume path
/// surfaces them and falls back (previous snapshot, then cold start)
/// instead of trusting bad state.
#[derive(Debug)]
pub enum CheckpointError {
    /// Underlying filesystem failure.
    Io(std::io::Error),
    /// File shorter than its own framing claims (torn write).
    Truncated {
        /// Bytes the framing requires.
        expected: u64,
        /// Bytes actually present.
        found: u64,
    },
    /// Leading magic bytes are not a checkpoint's.
    BadMagic,
    /// Snapshot written by a newer format version.
    FutureVersion {
        /// Version found in the file.
        found: u32,
        /// Newest version this build reads.
        supported: u32,
    },
    /// Payload digest mismatch (bit rot or a torn/overwritten payload).
    Corrupt {
        /// Digest recorded in the file.
        expected: u64,
        /// Digest of the payload as read.
        found: u64,
    },
    /// Snapshot was taken against different data.
    DatasetMismatch {
        /// Fingerprint of the data being resumed on.
        expected: u64,
        /// Fingerprint recorded in the snapshot.
        found: u64,
    },
    /// Snapshot is internally valid but does not match the fit being
    /// resumed (grid, strategy, problem shape).
    Incompatible(String),
}

impl CheckpointError {
    /// Stable short name per variant, for logs and test assertions.
    pub fn kind(&self) -> &'static str {
        match self {
            CheckpointError::Io(_) => "io",
            CheckpointError::Truncated { .. } => "truncated",
            CheckpointError::BadMagic => "bad_magic",
            CheckpointError::FutureVersion { .. } => "future_version",
            CheckpointError::Corrupt { .. } => "corrupt",
            CheckpointError::DatasetMismatch { .. } => "dataset_mismatch",
            CheckpointError::Incompatible(_) => "incompatible",
        }
    }
}

impl std::fmt::Display for CheckpointError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CheckpointError::Io(e) => write!(f, "checkpoint I/O error: {e}"),
            CheckpointError::Truncated { expected, found } => {
                write!(f, "checkpoint truncated: need {expected} bytes, found {found}")
            }
            CheckpointError::BadMagic => write!(f, "not a checkpoint file (bad magic)"),
            CheckpointError::FutureVersion { found, supported } => write!(
                f,
                "checkpoint format v{found} is newer than supported v{supported}"
            ),
            CheckpointError::Corrupt { expected, found } => write!(
                f,
                "checkpoint payload corrupt: digest {found:016x} != recorded {expected:016x}"
            ),
            CheckpointError::DatasetMismatch { expected, found } => write!(
                f,
                "checkpoint belongs to dataset {found:016x}, not {expected:016x}"
            ),
            CheckpointError::Incompatible(msg) => write!(f, "checkpoint incompatible: {msg}"),
        }
    }
}

impl From<std::io::Error> for CheckpointError {
    fn from(e: std::io::Error) -> Self {
        CheckpointError::Io(e)
    }
}

/// One recorded path step, mirroring
/// [`crate::slope::path::StepInfo`] with owned/encodable field types
/// (the `&'static str` strategy name travels as a string and is mapped
/// back on restore).
#[derive(Clone, Debug, PartialEq)]
pub struct StepRec {
    /// σ at this step.
    pub sigma: f64,
    /// Active coefficients.
    pub n_active: u64,
    /// Raw strong-rule screened-set size.
    pub n_screened_rule: u64,
    /// Final fitted set size.
    pub n_fitted: u64,
    /// Gap-safe screened-set size, if recorded.
    pub n_safe: Option<u64>,
    /// KKT violations.
    pub violations: u64,
    /// Solve/refit rounds.
    pub refits: u64,
    /// Inner FISTA iterations.
    pub solver_iterations: u64,
    /// Model deviance.
    pub deviance: f64,
    /// Fraction of null deviance explained.
    pub dev_ratio: f64,
    /// Seconds in screening.
    pub t_screen: f64,
    /// Seconds in the reduced solver.
    pub t_solve: f64,
    /// Seconds in full-gradient + KKT checks.
    pub t_kkt: f64,
    /// Whether every inner solve certified.
    pub solver_converged: bool,
    /// Full-design-equivalent gradient sweeps.
    pub full_grad_sweeps: f64,
    /// Safe-universe size (gap-driven only).
    pub n_universe: Option<u64>,
    /// Certified duality gap (gap-driven only).
    pub gap: Option<f64>,
    /// Ladder rescue strategy name, if the step degraded.
    pub degraded_to: Option<String>,
}

/// Cross-step dual state of the gap-driven strategies at the snapshot
/// point: the sphere reference (working residual + cached gradient
/// magnitudes at the last exact full sweep), the current
/// per-coefficient magnitude bounds, the loss there, and whether the
/// caller's gradient buffer was exact over every coefficient.
#[derive(Clone, Debug, PartialEq)]
pub struct GapSnap {
    /// Working residual at the sphere reference (length `n·m`).
    pub ref_h: Vec<f64>,
    /// `|x_jᵀ h_ref|` per coefficient at the reference (length `p·m`).
    pub ref_gmag: Vec<f64>,
    /// Current gradient-magnitude upper bounds (length `p·m`).
    pub grad_bound: Vec<f64>,
    /// `f(β)` at the snapshot point.
    pub loss: f64,
    /// Whether the gradient buffer was exact over every coefficient.
    pub grad_is_exact: bool,
}

/// Full path-fit state at one σ-step boundary.
#[derive(Clone, Debug, PartialEq)]
pub struct Snapshot {
    /// Dataset content fingerprint (ingest / canonical spec).
    pub dataset_fp: u64,
    /// Problem fingerprint ([`problem_fingerprint`]).
    pub problem_fp: u64,
    /// Grid fingerprint ([`grid_fingerprint`]).
    pub grid_fp: u64,
    /// Strategy name the fit ran under.
    pub strategy: String,
    /// σ index the resumed loop enters at (= completed steps).
    pub next_step: u64,
    /// Total coefficients `p·m`.
    pub pt: u64,
    /// Residual length `n·m`.
    pub nm: u64,
    /// Dense solution at the boundary.
    pub beta: Vec<f64>,
    /// Gradient buffer as the loop held it (exact for the heuristic
    /// strategies; exact-on-universe for gap-driven ones).
    pub grad: Vec<f64>,
    /// Linear predictor as the last solve left it.
    pub eta: Vec<f64>,
    /// Working residual at `eta`.
    pub h: Vec<f64>,
    /// Violations accumulated so far.
    pub total_violations: u64,
    /// Gradient sweeps accumulated so far.
    pub total_grad_sweeps: f64,
    /// σ values visited (including step 0).
    pub sigmas: Vec<f64>,
    /// Sparse per-step solutions.
    pub betas: Vec<Vec<(u64, f64)>>,
    /// Per-step records (parallel to `sigmas`).
    pub steps: Vec<StepRec>,
    /// Gap-driven dual state, present iff the strategy is gap-driven.
    pub gap: Option<GapSnap>,
}

/// Fingerprint of the problem a fit runs on: family, shapes, and the
/// response bits. The response is produced in the standardized column
/// coordinates ingest recorded, so this pins the `ColumnStats` identity
/// of the fit alongside the dataset content fingerprint.
pub fn problem_fingerprint(prob: &Problem) -> u64 {
    let mut fp = fnv1a(FNV_BASIS, prob.family.name().as_bytes());
    fp = fnv1a(fp, &(prob.n() as u64).to_le_bytes());
    fp = fnv1a(fp, &(prob.p() as u64).to_le_bytes());
    fp = fnv1a(fp, &(prob.family.n_classes() as u64).to_le_bytes());
    for &v in &prob.y {
        fp = fnv1a(fp, &v.to_bits().to_le_bytes());
    }
    fp
}

/// Fingerprint of the penalty grid: λ sequence bits and the σ grid bits.
/// The grid is recomputed deterministically from the β = 0 gradient on
/// resume; matching fingerprints prove the recomputation landed on the
/// same grid the snapshot was taken on.
pub fn grid_fingerprint(lambda_base: &[f64], sigmas: &[f64]) -> u64 {
    let mut fp = fnv1a(FNV_BASIS, &(lambda_base.len() as u64).to_le_bytes());
    for &l in lambda_base {
        fp = fnv1a(fp, &l.to_bits().to_le_bytes());
    }
    fp = fnv1a(fp, &(sigmas.len() as u64).to_le_bytes());
    for &s in sigmas {
        fp = fnv1a(fp, &s.to_bits().to_le_bytes());
    }
    fp
}

// ---------------------------------------------------------------------
// binary encoding
// ---------------------------------------------------------------------

struct Enc {
    buf: Vec<u8>,
}

impl Enc {
    fn new() -> Enc {
        Enc { buf: Vec::new() }
    }
    fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }
    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn f64(&mut self, v: f64) {
        self.u64(v.to_bits());
    }
    fn bool(&mut self, v: bool) {
        self.u8(v as u8);
    }
    fn str(&mut self, s: &str) {
        self.u64(s.len() as u64);
        self.buf.extend_from_slice(s.as_bytes());
    }
    fn vec_f64(&mut self, v: &[f64]) {
        self.u64(v.len() as u64);
        for &x in v {
            self.f64(x);
        }
    }
    fn opt_u64(&mut self, v: Option<u64>) {
        match v {
            Some(x) => {
                self.u8(1);
                self.u64(x);
            }
            None => self.u8(0),
        }
    }
    fn opt_f64(&mut self, v: Option<f64>) {
        match v {
            Some(x) => {
                self.u8(1);
                self.f64(x);
            }
            None => self.u8(0),
        }
    }
    fn opt_str(&mut self, v: Option<&str>) {
        match v {
            Some(s) => {
                self.u8(1);
                self.str(s);
            }
            None => self.u8(0),
        }
    }
}

struct Dec<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Dec<'a> {
    fn new(b: &'a [u8]) -> Dec<'a> {
        Dec { b, pos: 0 }
    }
    fn take(&mut self, n: usize) -> Result<&'a [u8], CheckpointError> {
        if self.pos + n > self.b.len() {
            return Err(CheckpointError::Truncated {
                expected: (self.pos + n) as u64,
                found: self.b.len() as u64,
            });
        }
        let s = &self.b[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }
    fn u8(&mut self) -> Result<u8, CheckpointError> {
        Ok(self.take(1)?[0])
    }
    fn u64(&mut self) -> Result<u64, CheckpointError> {
        let s = self.take(8)?;
        Ok(u64::from_le_bytes(s.try_into().expect("8-byte slice")))
    }
    fn f64(&mut self) -> Result<f64, CheckpointError> {
        Ok(f64::from_bits(self.u64()?))
    }
    fn bool(&mut self) -> Result<bool, CheckpointError> {
        Ok(self.u8()? != 0)
    }
    /// Bounded length read: a corrupted length field must surface as
    /// `Truncated`, not as a capacity panic on a garbage allocation.
    fn len(&mut self) -> Result<usize, CheckpointError> {
        let n = self.u64()?;
        let remaining = (self.b.len() - self.pos) as u64;
        if n > remaining {
            return Err(CheckpointError::Truncated {
                expected: self.pos as u64 + n,
                found: self.b.len() as u64,
            });
        }
        Ok(n as usize)
    }
    fn str(&mut self) -> Result<String, CheckpointError> {
        let n = self.len()?;
        let s = self.take(n)?;
        String::from_utf8(s.to_vec())
            .map_err(|_| CheckpointError::Incompatible("non-UTF8 string field".to_string()))
    }
    fn vec_f64(&mut self) -> Result<Vec<f64>, CheckpointError> {
        let n = self.len()?;
        let mut v = Vec::with_capacity(n.min(self.b.len() / 8 + 1));
        for _ in 0..n {
            v.push(self.f64()?);
        }
        Ok(v)
    }
    fn opt_u64(&mut self) -> Result<Option<u64>, CheckpointError> {
        Ok(if self.u8()? != 0 { Some(self.u64()?) } else { None })
    }
    fn opt_f64(&mut self) -> Result<Option<f64>, CheckpointError> {
        Ok(if self.u8()? != 0 { Some(self.f64()?) } else { None })
    }
    fn opt_str(&mut self) -> Result<Option<String>, CheckpointError> {
        Ok(if self.u8()? != 0 { Some(self.str()?) } else { None })
    }
}

impl Snapshot {
    fn encode_payload(&self) -> Vec<u8> {
        let mut e = Enc::new();
        e.u64(self.dataset_fp);
        e.u64(self.problem_fp);
        e.u64(self.grid_fp);
        e.str(&self.strategy);
        e.u64(self.next_step);
        e.u64(self.pt);
        e.u64(self.nm);
        e.vec_f64(&self.beta);
        e.vec_f64(&self.grad);
        e.vec_f64(&self.eta);
        e.vec_f64(&self.h);
        e.u64(self.total_violations);
        e.f64(self.total_grad_sweeps);
        e.vec_f64(&self.sigmas);
        e.u64(self.betas.len() as u64);
        for step in &self.betas {
            e.u64(step.len() as u64);
            for &(i, v) in step {
                e.u64(i);
                e.f64(v);
            }
        }
        e.u64(self.steps.len() as u64);
        for s in &self.steps {
            e.f64(s.sigma);
            e.u64(s.n_active);
            e.u64(s.n_screened_rule);
            e.u64(s.n_fitted);
            e.opt_u64(s.n_safe);
            e.u64(s.violations);
            e.u64(s.refits);
            e.u64(s.solver_iterations);
            e.f64(s.deviance);
            e.f64(s.dev_ratio);
            e.f64(s.t_screen);
            e.f64(s.t_solve);
            e.f64(s.t_kkt);
            e.bool(s.solver_converged);
            e.f64(s.full_grad_sweeps);
            e.opt_u64(s.n_universe);
            e.opt_f64(s.gap);
            e.opt_str(s.degraded_to.as_deref());
        }
        match &self.gap {
            Some(g) => {
                e.u8(1);
                e.vec_f64(&g.ref_h);
                e.vec_f64(&g.ref_gmag);
                e.vec_f64(&g.grad_bound);
                e.f64(g.loss);
                e.bool(g.grad_is_exact);
            }
            None => e.u8(0),
        }
        e.buf
    }

    fn decode_payload(payload: &[u8]) -> Result<Snapshot, CheckpointError> {
        let mut d = Dec::new(payload);
        let dataset_fp = d.u64()?;
        let problem_fp = d.u64()?;
        let grid_fp = d.u64()?;
        let strategy = d.str()?;
        let next_step = d.u64()?;
        let pt = d.u64()?;
        let nm = d.u64()?;
        let beta = d.vec_f64()?;
        let grad = d.vec_f64()?;
        let eta = d.vec_f64()?;
        let h = d.vec_f64()?;
        let total_violations = d.u64()?;
        let total_grad_sweeps = d.f64()?;
        let sigmas = d.vec_f64()?;
        let n_betas = d.len()?;
        let mut betas = Vec::with_capacity(n_betas.min(payload.len() + 1));
        for _ in 0..n_betas {
            let n = d.len()?;
            let mut step = Vec::with_capacity(n.min(payload.len() / 16 + 1));
            for _ in 0..n {
                let i = d.u64()?;
                let v = d.f64()?;
                step.push((i, v));
            }
            betas.push(step);
        }
        let n_steps = d.len()?;
        let mut steps = Vec::with_capacity(n_steps.min(payload.len() + 1));
        for _ in 0..n_steps {
            steps.push(StepRec {
                sigma: d.f64()?,
                n_active: d.u64()?,
                n_screened_rule: d.u64()?,
                n_fitted: d.u64()?,
                n_safe: d.opt_u64()?,
                violations: d.u64()?,
                refits: d.u64()?,
                solver_iterations: d.u64()?,
                deviance: d.f64()?,
                dev_ratio: d.f64()?,
                t_screen: d.f64()?,
                t_solve: d.f64()?,
                t_kkt: d.f64()?,
                solver_converged: d.bool()?,
                full_grad_sweeps: d.f64()?,
                n_universe: d.opt_u64()?,
                gap: d.opt_f64()?,
                degraded_to: d.opt_str()?,
            });
        }
        let gap = if d.u8()? != 0 {
            Some(GapSnap {
                ref_h: d.vec_f64()?,
                ref_gmag: d.vec_f64()?,
                grad_bound: d.vec_f64()?,
                loss: d.f64()?,
                grad_is_exact: d.bool()?,
            })
        } else {
            None
        };
        Ok(Snapshot {
            dataset_fp,
            problem_fp,
            grid_fp,
            strategy,
            next_step,
            pt,
            nm,
            beta,
            grad,
            eta,
            h,
            total_violations,
            total_grad_sweeps,
            sigmas,
            betas,
            steps,
            gap,
        })
    }

    /// Serialize to the on-disk framing: magic, version, payload length,
    /// payload, trailing FNV-1a digest.
    pub fn to_bytes(&self) -> Vec<u8> {
        let payload = self.encode_payload();
        let mut out = Vec::with_capacity(payload.len() + 32);
        out.extend_from_slice(&MAGIC);
        out.extend_from_slice(&VERSION.to_le_bytes());
        out.extend_from_slice(&(payload.len() as u64).to_le_bytes());
        let digest = fnv1a(FNV_BASIS, &payload);
        out.extend_from_slice(&payload);
        out.extend_from_slice(&digest.to_le_bytes());
        out
    }

    /// Decode from the on-disk framing, verifying magic, version, length
    /// and digest. Every malformation is a typed [`CheckpointError`].
    pub fn from_bytes(bytes: &[u8]) -> Result<Snapshot, CheckpointError> {
        if bytes.len() < MAGIC.len() + 4 + 8 + 8 {
            return Err(CheckpointError::Truncated {
                expected: (MAGIC.len() + 4 + 8 + 8) as u64,
                found: bytes.len() as u64,
            });
        }
        if bytes[..8] != MAGIC {
            return Err(CheckpointError::BadMagic);
        }
        let version = u32::from_le_bytes(bytes[8..12].try_into().expect("4 bytes"));
        if version > VERSION {
            return Err(CheckpointError::FutureVersion { found: version, supported: VERSION });
        }
        let plen = u64::from_le_bytes(bytes[12..20].try_into().expect("8 bytes"));
        let need = 20u64 + plen + 8;
        if (bytes.len() as u64) < need {
            return Err(CheckpointError::Truncated { expected: need, found: bytes.len() as u64 });
        }
        let payload = &bytes[20..20 + plen as usize];
        let recorded =
            u64::from_le_bytes(bytes[20 + plen as usize..28 + plen as usize].try_into().expect("8"));
        let digest = fnv1a(FNV_BASIS, payload);
        if digest != recorded {
            return Err(CheckpointError::Corrupt { expected: recorded, found: digest });
        }
        Snapshot::decode_payload(payload)
    }
}

/// The rotated previous-snapshot path: `<path>.prev`.
pub fn prev_path(path: &Path) -> PathBuf {
    let mut s = path.as_os_str().to_os_string();
    s.push(".prev");
    PathBuf::from(s)
}

fn tmp_path(path: &Path) -> PathBuf {
    let mut s = path.as_os_str().to_os_string();
    s.push(".tmp");
    PathBuf::from(s)
}

/// Write `snap` atomically: serialize to `<path>.tmp`, fsync, rotate the
/// current snapshot to `<path>.prev`, rename the temp over `<path>`, and
/// (on Unix) fsync the directory so the rename itself is durable.
/// Returns the byte count written. Bumps the `checkpoint_writes` /
/// `checkpoint_bytes` counters.
pub fn write_atomic(path: &Path, snap: &Snapshot) -> Result<u64, CheckpointError> {
    let bytes = snap.to_bytes();
    let tmp = tmp_path(path);
    {
        let mut f = fs::File::create(&tmp)?;
        f.write_all(&bytes)?;
        f.sync_all()?;
    }
    if path.exists() {
        // Keep one good snapshot behind the new one: a torn *rename* (or
        // a fault-injected truncation of the fresh file) falls back here.
        fs::rename(path, prev_path(path))?;
    }
    fs::rename(&tmp, path)?;
    #[cfg(unix)]
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            if let Ok(d) = fs::File::open(dir) {
                let _ = d.sync_all();
            }
        }
    }
    obsreg::CKPT_WRITES.inc();
    obsreg::CKPT_BYTES.add(bytes.len() as u64);
    Ok(bytes.len() as u64)
}

/// Load and verify the snapshot at `path`.
pub fn load(path: &Path) -> Result<Snapshot, CheckpointError> {
    let bytes = fs::read(path)?;
    Snapshot::from_bytes(&bytes)
}

/// Load `path`, falling back to `<path>.prev` when the primary snapshot
/// is missing or fails verification. A failed primary is logged and
/// counted (`checkpoint_corrupt_skips`) unless it simply does not exist.
/// Returns the snapshot plus whether it came from the fallback; when
/// both fail, the *primary's* error is returned (the more recent state
/// is the one the caller asked about).
pub fn load_with_fallback(path: &Path) -> Result<(Snapshot, bool), CheckpointError> {
    match load(path) {
        Ok(snap) => Ok((snap, false)),
        Err(primary) => {
            if !matches!(&primary, CheckpointError::Io(e) if e.kind() == std::io::ErrorKind::NotFound)
            {
                obsreg::CKPT_CORRUPT_SKIPS.inc();
                eprintln!(
                    "checkpoint: {} unusable ({primary}); trying previous snapshot",
                    path.display()
                );
            }
            match load(&prev_path(path)) {
                Ok(snap) => Ok((snap, true)),
                Err(_) => Err(primary),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{Design, Mat};
    use crate::slope::family::Family;

    fn sample_snapshot(gap: bool) -> Snapshot {
        Snapshot {
            dataset_fp: 0xfeed_beef_dead_cafe,
            problem_fp: 0x1234_5678_9abc_def0,
            grid_fp: 42,
            strategy: "hybrid".to_string(),
            next_step: 3,
            pt: 4,
            nm: 2,
            beta: vec![0.0, -1.5, 3.25, f64::MIN_POSITIVE],
            grad: vec![1.0, 2.0, -0.0, 4.0],
            eta: vec![0.5, -0.5],
            h: vec![0.25, -0.25],
            total_violations: 7,
            total_grad_sweeps: 5.5,
            sigmas: vec![1.0, 0.9, 0.8],
            betas: vec![Vec::new(), vec![(1, -1.5)], vec![(1, -1.5), (2, 3.25)]],
            steps: vec![
                StepRec {
                    sigma: 1.0,
                    n_active: 0,
                    n_screened_rule: 0,
                    n_fitted: 0,
                    n_safe: None,
                    violations: 0,
                    refits: 0,
                    solver_iterations: 0,
                    deviance: 2.0,
                    dev_ratio: 0.0,
                    t_screen: 0.0,
                    t_solve: 0.0,
                    t_kkt: 0.0,
                    solver_converged: true,
                    full_grad_sweeps: 1.0,
                    n_universe: None,
                    gap: None,
                    degraded_to: None,
                },
                StepRec {
                    sigma: 0.9,
                    n_active: 1,
                    n_screened_rule: 2,
                    n_fitted: 2,
                    n_safe: Some(3),
                    violations: 1,
                    refits: 2,
                    solver_iterations: 40,
                    deviance: 1.5,
                    dev_ratio: 0.25,
                    t_screen: 1e-4,
                    t_solve: 2e-3,
                    t_kkt: 3e-4,
                    solver_converged: true,
                    full_grad_sweeps: 1.5,
                    n_universe: Some(4),
                    gap: Some(1e-7),
                    degraded_to: Some("strong".to_string()),
                },
            ],
            gap: gap.then(|| GapSnap {
                ref_h: vec![0.25, -0.25],
                ref_gmag: vec![1.0, 2.0, 0.0, 4.0],
                grad_bound: vec![1.0, 2.5, 0.5, 4.0],
                loss: 0.75,
                grad_is_exact: false,
            }),
        }
    }

    #[test]
    fn roundtrip_is_bitwise_exact() {
        for gap in [false, true] {
            let snap = sample_snapshot(gap);
            let back = Snapshot::from_bytes(&snap.to_bytes()).expect("roundtrip");
            assert_eq!(back, snap);
            // -0.0 and subnormals survive as bits, not just values
            assert_eq!(back.grad[2].to_bits(), (-0.0f64).to_bits());
            assert_eq!(back.beta[3].to_bits(), f64::MIN_POSITIVE.to_bits());
        }
    }

    #[test]
    fn truncated_file_is_typed_never_a_panic() {
        let bytes = sample_snapshot(true).to_bytes();
        // every prefix length must yield a typed error, not a panic
        for cut in [0, 4, 11, 19, 20, bytes.len() / 2, bytes.len() - 1] {
            let err = Snapshot::from_bytes(&bytes[..cut]).expect_err("truncated must fail");
            assert!(
                matches!(err, CheckpointError::Truncated { .. }),
                "cut at {cut}: got {}",
                err.kind()
            );
        }
    }

    #[test]
    fn bit_flip_in_payload_is_corrupt() {
        let mut bytes = sample_snapshot(false).to_bytes();
        let mid = 20 + (bytes.len() - 28) / 2;
        bytes[mid] ^= 0x40;
        let err = Snapshot::from_bytes(&bytes).expect_err("flip must fail");
        assert_eq!(err.kind(), "corrupt");
    }

    #[test]
    fn future_version_and_bad_magic_are_typed() {
        let mut bytes = sample_snapshot(false).to_bytes();
        bytes[8..12].copy_from_slice(&(VERSION + 1).to_le_bytes());
        assert_eq!(Snapshot::from_bytes(&bytes).unwrap_err().kind(), "future_version");
        let mut bytes = sample_snapshot(false).to_bytes();
        bytes[0] = b'X';
        assert_eq!(Snapshot::from_bytes(&bytes).unwrap_err().kind(), "bad_magic");
    }

    #[test]
    fn corrupted_length_field_cannot_over_allocate() {
        let mut bytes = sample_snapshot(false).to_bytes();
        // vec length fields live inside the payload; blow one up to a
        // huge value and fix the digest so the framing passes — decode
        // must fail bounded (Truncated), not attempt a 2^60 allocation.
        let beta_len_off = 20 + 8 + 8 + 8 + (8 + "hybrid".len()) + 8 + 8 + 8;
        bytes[beta_len_off..beta_len_off + 8].copy_from_slice(&(1u64 << 60).to_le_bytes());
        let plen = u64::from_le_bytes(bytes[12..20].try_into().unwrap()) as usize;
        let digest = fnv1a(FNV_BASIS, &bytes[20..20 + plen]);
        let dpos = 20 + plen;
        bytes[dpos..dpos + 8].copy_from_slice(&digest.to_le_bytes());
        let err = Snapshot::from_bytes(&bytes).expect_err("bogus length must fail");
        assert_eq!(err.kind(), "truncated");
    }

    #[test]
    fn atomic_write_rotates_previous_snapshot() {
        let dir = std::env::temp_dir().join(format!("slope-ckpt-{}-rotate", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("fit.ckpt");
        let mut snap = sample_snapshot(false);
        write_atomic(&path, &snap).unwrap();
        snap.next_step = 4;
        write_atomic(&path, &snap).unwrap();
        let (cur, from_prev) = load_with_fallback(&path).unwrap();
        assert!(!from_prev);
        assert_eq!(cur.next_step, 4);
        let prev = load(&prev_path(&path)).unwrap();
        assert_eq!(prev.next_step, 3);
        // corrupt the primary: fallback serves the previous snapshot
        std::fs::write(&path, b"SLPCKPT1garbage").unwrap();
        let (fell_back, from_prev) = load_with_fallback(&path).unwrap();
        assert!(from_prev);
        assert_eq!(fell_back.next_step, 3);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn fingerprints_separate_problems_and_grids() {
        let x = Mat::from_rows(&[&[1.0, 0.5], &[-0.5, 1.0]]);
        let p1 = Problem::new(Design::Dense(x.clone()), vec![1.0, 2.0], Family::Gaussian);
        let p2 = Problem::new(Design::Dense(x), vec![1.0, 2.5], Family::Gaussian);
        assert_ne!(problem_fingerprint(&p1), problem_fingerprint(&p2));
        assert_eq!(problem_fingerprint(&p1), problem_fingerprint(&p1));
        let g1 = grid_fingerprint(&[1.0, 0.5], &[1.0, 0.9]);
        let g2 = grid_fingerprint(&[1.0, 0.5], &[1.0, 0.8]);
        assert_ne!(g1, g2);
    }
}

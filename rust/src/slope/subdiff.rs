//! Theorem 1: the subdifferential of the sorted-ℓ1 norm, and the KKT
//! stationarity check `0 ∈ ∇f(β) + ∂J(β; λ)` that safeguards the
//! heuristic screening rule (§2.2.2).

use crate::linalg::ops::cumsum;
use crate::slope::sorted::clusters;

/// Membership test `g ∈ ∂J(β; λ)` per Theorem 1.
///
/// For each cluster `A_i` of equal `|β|` (eq. 2):
/// * `cumsum(|g_{A_i}|↓ − λ_{R_{A_i}}) ≤ tol` elementwise, where the λ
///   block is the slice of λ at the cluster's global rank positions, and
/// * if the cluster is active (`β_{A_i} ≠ 0`), additionally
///   `Σ_{j∈A_i} (|g_j| − λ_{R(g)_j}) = 0` (within `tol`) and
///   `sign(g_j) = sign(β_j)` for all members.
pub fn in_subdifferential(beta: &[f64], g: &[f64], lambda: &[f64], tol: f64) -> bool {
    assert_eq!(beta.len(), g.len());
    assert!(lambda.len() >= beta.len());
    let cls = clusters(beta);
    let mut lambda_pos = 0usize; // global rank cursor into λ
    for cl in &cls {
        let card = cl.members.len();
        let lam_block = &lambda[lambda_pos..lambda_pos + card];
        // |g| over the cluster, sorted descending (the subdifferential is
        // invariant to within-cluster permutations — Remark 1).
        let mut gmag: Vec<f64> = cl.members.iter().map(|&j| g[j].abs()).collect();
        gmag.sort_unstable_by(|a, b| b.total_cmp(a)); // NaN-tolerant: runs on every KKT check
        let diffs: Vec<f64> = gmag.iter().zip(lam_block).map(|(gi, li)| gi - li).collect();
        let cs = cumsum(&diffs);
        if cs.iter().any(|&c| c > tol) {
            return false;
        }
        if cl.magnitude > 0.0 {
            // active cluster: the total must be exactly zero...
            let total = cs.last().copied().unwrap_or(0.0);
            if total.abs() > tol {
                return false;
            }
            // ...and subgradient signs must match coefficient signs.
            for &j in &cl.members {
                if g[j] != 0.0 && g[j].signum() != beta[j].signum() {
                    return false;
                }
            }
        }
        lambda_pos += card;
    }
    true
}

/// KKT stationarity check for the SLOPE problem `min f(β) + J(β; λ)`:
/// verifies `−∇f(β) ∈ ∂J(β; λ)`.
pub fn kkt_optimal(beta: &[f64], grad: &[f64], lambda: &[f64], tol: f64) -> bool {
    let neg: Vec<f64> = grad.iter().map(|g| -g).collect();
    in_subdifferential(beta, &neg, lambda, tol)
}

/// Maximum KKT infeasibility of the *inactive-set condition*: the largest
/// positive prefix of `cumsum(|g|↓ − λ)`. Zero (≤ tol) at any stationary
/// point; used as a solver convergence diagnostic and in the safeguarded
/// screening loop.
pub fn kkt_infeasibility(grad: &[f64], lambda: &[f64]) -> f64 {
    let mut mags: Vec<f64> = grad.iter().map(|g| g.abs()).collect();
    mags.sort_unstable_by(|a, b| b.total_cmp(a));
    let mut acc = 0.0f64;
    let mut worst = 0.0f64;
    for (m, l) in mags.iter().zip(lambda) {
        acc += m - l;
        worst = worst.max(acc);
    }
    worst
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::check::{ensure, forall, gen, Config};
    use crate::slope::prox::prox_sorted_l1;

    #[test]
    fn zero_beta_small_gradient_is_member() {
        // β = 0: need cumsum(|g|↓ − λ) ≤ 0.
        let beta = [0.0, 0.0];
        let lambda = [2.0, 1.0];
        assert!(in_subdifferential(&beta, &[1.5, 1.0], &lambda, 1e-12));
        assert!(in_subdifferential(&beta, &[2.0, 1.0], &lambda, 1e-12));
        // |g|↓ = (2.5, 0): first prefix breaks.
        assert!(!in_subdifferential(&beta, &[0.0, 2.5], &lambda, 1e-12));
        // prefixes: 1.9-2 = -0.1, then +1.5-1 = 0.4 > 0: breaks.
        assert!(!in_subdifferential(&beta, &[1.9, 1.5], &lambda, 1e-12));
    }

    #[test]
    fn active_cluster_requires_exact_total() {
        let beta = [1.0];
        let lambda = [2.0];
        assert!(in_subdifferential(&beta, &[2.0], &lambda, 1e-12));
        assert!(!in_subdifferential(&beta, &[1.5], &lambda, 1e-12)); // total < 0
        assert!(!in_subdifferential(&beta, &[2.5], &lambda, 1e-12)); // prefix > 0
        assert!(!in_subdifferential(&beta, &[-2.0], &lambda, 1e-12)); // sign flip
    }

    #[test]
    fn tied_cluster_allows_redistribution() {
        // β = (1, 1): the cluster {0,1} uses λ = (3, 1); any |g| with
        // |g|↓ prefix sums ≤ (3, 4) and total = 4 works.
        let beta = [1.0, 1.0];
        let lambda = [3.0, 1.0];
        assert!(in_subdifferential(&beta, &[3.0, 1.0], &lambda, 1e-12));
        assert!(in_subdifferential(&beta, &[2.0, 2.0], &lambda, 1e-12));
        assert!(in_subdifferential(&beta, &[2.5, 1.5], &lambda, 1e-12));
        // prefix violation: 3.5 > 3
        assert!(!in_subdifferential(&beta, &[3.5, 0.5], &lambda, 1e-12));
        // wrong total
        assert!(!in_subdifferential(&beta, &[2.0, 1.0], &lambda, 1e-12));
    }

    #[test]
    fn prox_fixed_point_is_kkt_optimal() {
        // β* = prox(β* − ∇f(β*)) ⇔ KKT; here f(β) = ½‖β − v‖² so
        // ∇f(β*) = β* − v and the condition is v − β* ∈ ∂J(β*).
        forall(
            Config { cases: 200, seed: 0x31 },
            |rng| {
                let v = gen::tied_vec(rng, 1, 20);
                let lam = gen::lambda_seq(rng, v.len());
                (v, lam)
            },
            |(v, lam)| {
                let b = prox_sorted_l1(v, lam);
                let grad: Vec<f64> = b.iter().zip(v).map(|(bi, vi)| bi - vi).collect();
                ensure(kkt_optimal(&b, &grad, lam, 1e-8), "prox output fails KKT")
            },
        );
    }

    #[test]
    fn infeasibility_zero_iff_inactive_condition_holds() {
        let lambda = [2.0, 1.0, 0.5];
        assert_eq!(kkt_infeasibility(&[1.0, 0.5, 0.2], &lambda), 0.0);
        assert!(kkt_infeasibility(&[2.5, 0.0, 0.0], &lambda) > 0.0);
        // redistribution: |g|↓ = (1.5, 1.5, 0): cumsum(−0.5, 0, −0.5) ≤ 0
        assert_eq!(kkt_infeasibility(&[1.5, 1.5, 0.0], &lambda), 0.0);
    }

    #[test]
    fn infeasibility_matches_membership_at_zero() {
        forall(
            Config { cases: 200, seed: 0x32 },
            |rng| {
                let g = gen::normal_vec(rng, 1, 15);
                let lam = gen::lambda_seq(rng, g.len());
                (g, lam)
            },
            |(g, lam)| {
                let zero = vec![0.0; g.len()];
                let member = in_subdifferential(&zero, g, lam, 1e-12);
                let infeas = kkt_infeasibility(g, lam);
                ensure(
                    member == (infeas <= 1e-12),
                    format!("member={member} infeas={infeas}"),
                )
            },
        );
    }
}

//! The SLOPE machinery: everything §2 of the paper defines.
//!
//! * [`sorted`] — the sorted-ℓ1 norm `J(β; λ)`, the ordering operators
//!   `O(·)`/`R(·)` and cluster extraction (paper §1.2, eq. 2).
//! * [`prox`] — the proximal operator of `J` (stack-based PAVA, `O(p)`
//!   after sorting).
//! * [`lambda`] — the BH, Gaussian, OSCAR and lasso penalty sequences and
//!   the σ-parameterized regularization path (§3.1.1–3.1.2).
//! * [`subdiff`] — Theorem 1: membership test for `∂J(β; λ)` and the KKT
//!   stationarity check used to safeguard the heuristic rule.
//! * [`screen`] — Algorithms 1–2, the strong rule for SLOPE, the lasso
//!   strong rule (Proposition 3) and a gap-safe-style baseline (Figure 1).
//! * [`family`] — the four GLM objectives of §3.2.3 (OLS, logistic,
//!   Poisson, multinomial).
//! * [`dual`] — Fenchel duality: dual-feasible points from the working
//!   residual, per-family dual objectives, and the duality-gap
//!   certificate the solver and the hybrid screen both run on.
//! * [`safe`] — Elvira–Herzet-style sphere tests: *certified* per-σ
//!   discards from a dual point and its gap, with a reference-point
//!   bound so re-tests cost no design product.
//! * [`fista`] — the accelerated proximal-gradient solver (the paper's
//!   solver of record) on the *reduced* (screened) problem, with
//!   displacement, KKT-verified and gap-certified stopping modes.
//! * [`path`] — the regularization-path driver with the no-screening,
//!   strong-set (Algorithm 3), previous-set (Algorithm 4), safe-only and
//!   gap-hybrid (safe + strong working set) strategies, plus the
//!   degradation ladder that rescues non-converged steps under
//!   progressively more conservative strategies.
//! * [`cancel`] — the cooperative [`cancel::CancelToken`] checked every
//!   FISTA iteration and every path σ-step; backs per-request deadlines
//!   in the serve layer.
//! * [`checkpoint`] — crash-safe path-fit snapshots: atomic
//!   fsync-and-rename writes, FNV-digested framing, a dataset/problem/
//!   grid fingerprint chain, and typed corruption errors backing the
//!   resume entry points in [`path`] (DESIGN.md §13).

pub mod cancel;
pub mod checkpoint;
pub mod dual;
pub mod family;
pub mod fista;
pub mod lambda;
pub mod path;
pub mod prox;
pub mod safe;
pub mod screen;
pub mod sorted;
pub mod subdiff;

pub use family::{Family, Problem};
pub use lambda::{LambdaKind, PathConfig};
pub use path::{PathFit, Strategy};

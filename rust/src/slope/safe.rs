//! Elvira–Herzet-style *safe* sphere tests for SLOPE (PAPERS.md: "Safe
//! rules for the identification of zeros in the solutions of the SLOPE
//! problem").
//!
//! Given any dual-feasible point `θ` with duality gap `G` for an
//! `L`-smooth loss, every dual-optimal `θ*` lies in the ball
//! `B(θ, √(2·L·G))` (strong convexity of `f*`). Theorem 1 forces every
//! *active* coordinate of the optimum to satisfy
//! `|x_jᵀθ*| = |∇f_j(β*)| ≥ λ_min` (the smallest penalty weight: an
//! active cluster's trailing prefix sum pins its smallest gradient
//! magnitude to at least its smallest λ-block entry). So
//!
//! ```text
//! |x_jᵀθ| + r·‖x_j‖ < λ_min   with   r = √(2·L·gap)
//! ```
//!
//! *certifies* `β*_j = 0` — a **permanent per-σ discard**, unlike the
//! heuristic strong rule, whose discards must be re-checked by a KKT
//! sweep. λ_min is the only per-coordinate threshold valid for the
//! sorted-ℓ1 dual ball, which is exactly why the safe rule alone is far
//! more conservative than the strong rule (Fig. 1) and why the hybrid
//! strategy layers the two (DESIGN.md §10).
//!
//! The screener additionally carries a **reference dual point** so the
//! test can run *without* a fresh full-design product: with
//! `c_j = |x_jᵀh_ref|` cached from a genuine full-gradient sweep,
//! `|x_jᵀh| ≤ c_j + ‖x_j‖·‖h − h_ref‖` bounds every coordinate's
//! magnitude at the current residual `h` in `O(1)` — upper bounds are
//! conservative in every consumer (feasibility scaling, sphere test),
//! so soundness is preserved while the `O(n·p)` sweep shrinks to the
//! surviving universe.

use std::sync::Arc;

use crate::linalg::ParConfig;
use crate::slope::family::Problem;

/// Reference-point state for the sphere tests. One per path fit; the
/// reference is (re)established on every full-gradient sweep for free.
#[derive(Clone, Debug, Default)]
pub struct SafeScreener {
    /// Design columns (`p`, not `p·m`).
    p: usize,
    /// `‖x_j‖₂` per design column (length `p`). Shared (`Arc`) so the
    /// serve registry's per-dataset cache hands them to every request
    /// without copying.
    col_norms: Arc<Vec<f64>>,
    /// Working residual at the reference point (length `n·m`).
    h_ref: Vec<f64>,
    /// `|x_jᵀ h_ref|` per flattened coefficient (length `p·m`) — the
    /// magnitudes of a full gradient, cached when it was last computed.
    xt_abs_ref: Vec<f64>,
}

impl SafeScreener {
    /// Build the screener for a problem: one `O(nnz)` column-norm sweep,
    /// no reference yet (the first full gradient provides it).
    pub fn new(prob: &Problem, par: ParConfig) -> Self {
        Self::from_norms(prob.p(), Arc::new(prob.x.col_norms_with(par)))
    }

    /// Build from already-computed column norms (`‖x_j‖`, length = design
    /// columns) — what lets a per-request `fit_point` stream skip both
    /// the column-norm pass and any copy of it (the serve registry
    /// caches one shared vector per dataset).
    pub fn from_norms(p: usize, col_norms: Arc<Vec<f64>>) -> Self {
        debug_assert_eq!(col_norms.len(), p);
        Self { p, col_norms, h_ref: Vec::new(), xt_abs_ref: Vec::new() }
    }

    /// True once a reference dual point has been recorded.
    pub fn has_reference(&self) -> bool {
        !self.xt_abs_ref.is_empty()
    }

    /// Record a reference point from a *full* gradient evaluation:
    /// `h` is the working residual, `grad = Xᵀh` over every coefficient.
    pub fn set_reference(&mut self, h: &[f64], grad: &[f64]) {
        self.h_ref.clear();
        self.h_ref.extend_from_slice(h);
        self.xt_abs_ref.clear();
        self.xt_abs_ref.extend(grad.iter().map(|g| g.abs()));
    }

    /// The stored reference point, if any: `(h_ref, |x_jᵀh_ref|)`. The
    /// magnitudes are already absolute values, so feeding them back
    /// through [`SafeScreener::set_reference`] (which takes `|·|` again —
    /// idempotent) reconstructs this screener's state bitwise. Backs the
    /// checkpoint serialization of the gap-driven path strategies.
    pub fn reference(&self) -> Option<(&[f64], &[f64])> {
        if self.has_reference() {
            Some((&self.h_ref, &self.xt_abs_ref))
        } else {
            None
        }
    }

    /// `‖h − h_ref‖₂` — the only quantity a bound refresh needs, and it
    /// lives in `R^{n·m}`, independent of `p`.
    pub fn ref_distance(&self, h: &[f64]) -> f64 {
        debug_assert_eq!(h.len(), self.h_ref.len());
        crate::linalg::ops::dist(h, &self.h_ref)
    }

    /// Column norm of a flattened coefficient (class-major layout: the
    /// class shares its column's norm).
    pub fn col_norm(&self, coef: usize) -> f64 {
        if self.col_norms.is_empty() {
            0.0
        } else {
            self.col_norms[coef % self.p]
        }
    }

    /// Upper bound on `|x_jᵀh|` at residual distance `d` from the
    /// reference (triangle inequality through the cached reference
    /// magnitudes). Requires a reference.
    pub fn mag_bound(&self, coef: usize, d: f64) -> f64 {
        debug_assert!(self.has_reference());
        self.xt_abs_ref[coef] + self.col_norm(coef) * d
    }

    /// Sphere radius `√(2·L·gap)` in dual space for an `L`-smooth loss
    /// (`L` = [`crate::slope::family::Family::hessian_bound`]); `None`
    /// for unbounded-curvature families (Poisson), which get no safe
    /// discards. A NaN gap (diverged solve) yields an *infinite* radius
    /// — nothing can be certified from a broken certificate — rather
    /// than the 0 that `gap.max(0.0)` would silently produce.
    pub fn radius(gap: f64, hessian_bound: Option<f64>) -> Option<f64> {
        hessian_bound.map(|l| {
            if gap.is_nan() {
                f64::INFINITY
            } else {
                (2.0 * l * gap.max(0.0)).sqrt()
            }
        })
    }

    /// The sphere test. `mag_h` upper-bounds `|x_jᵀh|` at the current
    /// point (exact values and [`SafeScreener::mag_bound`]s are both
    /// valid), `scale ≥ 1` is the dual feasibility scaling (`θ = −h/s`),
    /// `radius` the current `√(2·L·gap)`, `lam_min` the smallest
    /// σ-scaled penalty weight. Returns **true when the coefficient must
    /// be kept** — `false` is a certificate that `β*_j = 0` at this σ.
    pub fn keeps(&self, mag_h: f64, coef: usize, scale: f64, radius: f64, lam_min: f64) -> bool {
        let inv = if scale.is_finite() { 1.0 / scale } else { 0.0 };
        // NaN anywhere makes the comparison false-free: `!(x < y)` keeps
        // the coefficient, the conservative direction.
        !(mag_h * inv + radius * self.col_norm(coef) < lam_min)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::check::{ensure, forall, Config};
    use crate::linalg::ops::abs_sorted_desc;
    use crate::linalg::{Csc, Design, Mat};
    use crate::rng::Pcg64;
    use crate::slope::dual::duality_gap;
    use crate::slope::family::Family;
    use crate::slope::lambda::{bh_sequence, sigma_max};
    use crate::slope::path::{fit_point, zero_seed, NativeGradient, PathOptions, Strategy};
    use crate::slope::sorted::sl1_norm;

    fn gaussian_problem(seed: u64, n: usize, p: usize, k: usize, sparse: bool) -> Problem {
        let mut rng = Pcg64::new(seed);
        let mut x = Mat::zeros(n, p);
        for j in 0..p {
            for i in 0..n {
                if !sparse || rng.bernoulli(0.5) {
                    x.set(i, j, rng.normal());
                }
            }
        }
        let beta: Vec<f64> = (0..p).map(|j| if j < k { 2.0 * rng.sign() } else { 0.0 }).collect();
        let mut eta = vec![0.0; n];
        x.gemv(&beta, &mut eta);
        let y: Vec<f64> = eta.iter().map(|e| e + 0.3 * rng.normal()).collect();
        let mut design = if sparse {
            Design::Sparse(Csc::from_dense(&x))
        } else {
            Design::Dense(x)
        };
        design.standardize();
        Problem::new(design, y, Family::Gaussian)
    }

    #[test]
    fn radius_formula_and_families() {
        assert_eq!(SafeScreener::radius(0.0, Some(1.0)), Some(0.0));
        let r = SafeScreener::radius(2.0, Some(1.0)).unwrap();
        assert!((r - 2.0).abs() < 1e-12); // √(2·1·2) = 2
        let r = SafeScreener::radius(2.0, Some(0.25)).unwrap();
        assert!((r - 1.0).abs() < 1e-12); // binomial curvature tightens it
        assert_eq!(SafeScreener::radius(1.0, None), None); // Poisson: no safe rule
        // negative gap (rounding) clamps to zero radius, not NaN
        assert_eq!(SafeScreener::radius(-1e-18, Some(1.0)), Some(0.0));
        // NaN gap: infinite radius (nothing certifiable), never 0
        assert_eq!(SafeScreener::radius(f64::NAN, Some(1.0)), Some(f64::INFINITY));
    }

    #[test]
    fn keeps_is_conservative_on_nan_and_degenerate_shapes() {
        let s = SafeScreener::default(); // p = 0: no columns at all
        assert!(s.keeps(f64::NAN, 0, 1.0, 0.0, 1.0) || !s.keeps(0.0, 0, 1.0, 0.0, 1.0));
        // NaN magnitude must keep (conservative), never panic
        assert!(s.keeps(f64::NAN, 0, 1.0, 0.5, 1.0));
        // λ_min = 0: nothing is ever discarded (LHS ≥ 0 can't go below 0)
        assert!(s.keeps(0.0, 0, 1.0, 0.0, 0.0));
        // infinite scale (θ = 0) discards iff the radius term alone clears
        assert!(!s.keeps(5.0, 0, f64::INFINITY, 0.0, 1.0)); // col_norm 0 ⇒ LHS 0 < 1
    }

    #[test]
    fn screener_handles_n0_and_p1_designs() {
        // n = 0: empty residuals, zero norms — no panics anywhere.
        let prob = Problem::new(Design::Dense(Mat::zeros(0, 3)), Vec::new(), Family::Gaussian);
        let mut s = SafeScreener::new(&prob, ParConfig::serial());
        assert_eq!(s.col_norm(2), 0.0);
        s.set_reference(&[], &[0.0, 0.0, 0.0]);
        assert!(s.has_reference());
        assert_eq!(s.ref_distance(&[]), 0.0);
        assert_eq!(s.mag_bound(1, 0.0), 0.0);
        // p = 1: single-column design round-trips through the test.
        let prob = gaussian_problem(3, 10, 1, 1, false);
        let s1 = SafeScreener::new(&prob, ParConfig::serial());
        assert!(s1.col_norm(0) > 0.0);
        assert!(s1.keeps(1.0, 0, 1.0, 1.0, 0.5));
    }

    #[test]
    fn mag_bound_dominates_true_magnitude() {
        // |x_jᵀh| ≤ c_j + ‖x_j‖·‖h − h_ref‖ for arbitrary h, h_ref.
        forall(
            Config { cases: 80, seed: 0x5afe },
            |rng| {
                let n = 5 + rng.below(20) as usize;
                let p = 1 + rng.below(8) as usize;
                let seed = rng.below(1 << 30);
                let h: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
                let h_ref: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
                (n, p, seed, h, h_ref)
            },
            |(n, p, seed, h, h_ref)| {
                let prob = gaussian_problem(*seed, *n, *p, 1.min(*p), false);
                let mut scr = SafeScreener::new(&prob, ParConfig::serial());
                let mut gref = vec![0.0; *p];
                prob.gradient_from_h(h_ref, &mut gref);
                scr.set_reference(h_ref, &gref);
                let d = scr.ref_distance(h);
                let mut g = vec![0.0; *p];
                prob.gradient_from_h(h, &mut g);
                for j in 0..*p {
                    ensure(
                        g[j].abs() <= scr.mag_bound(j, d) + 1e-9,
                        format!("bound violated at {j}: |g|={} bound={}", g[j].abs(), scr.mag_bound(j, d)),
                    )?;
                }
                Ok(())
            },
        );
    }

    #[test]
    fn safe_rule_never_discards_active_predictor() {
        // The satellite proptest: run the sphere test at a loosely-solved
        // point and check its discards against a *tight* reference fit's
        // support — a safe discard of a truly active predictor is a
        // soundness bug, at any gap. Dense and sparse designs.
        forall(
            Config { cases: 25, seed: 0x5afe2 },
            |rng| {
                let n = 20 + rng.below(20) as usize;
                let p = 8 + rng.below(30) as usize;
                let seed = rng.below(1 << 30);
                let sparse = rng.bernoulli(0.4);
                let ratio = 0.25 + 0.5 * rng.next_f64();
                (n, p, seed, sparse, ratio)
            },
            |(n, p, seed, sparse, ratio)| {
                let prob = gaussian_problem(*seed, *n, *p, 3.min(p / 2).max(1), *sparse);
                let p = prob.p();
                let lam_base = bh_sequence(p, 0.1);
                // tight reference fit at σ = ratio·σ_max
                let mut opts = PathOptions::new(crate::slope::lambda::PathConfig::new(
                    crate::slope::lambda::LambdaKind::Bh { q: 0.1 },
                ))
                .with_strategy(Strategy::StrongSet);
                opts.fista.tol = 1e-11;
                let ng = NativeGradient(&prob);
                let zero = zero_seed(&prob, &opts, &ng);
                let sigma = zero.sigma * ratio;
                let tight = fit_point(&prob, &opts, &ng, sigma, &zero);
                // solidly-active coordinates only: a |β̂_j| at solver-noise
                // scale can differ from the true optimum's support, which
                // is a tolerance artifact, not a screening soundness issue
                let support: Vec<usize> = tight
                    .beta
                    .iter()
                    .enumerate()
                    .filter(|(_, &b)| b.abs() > 1e-6)
                    .map(|(j, _)| j)
                    .collect();
                let lam: Vec<f64> = lam_base.iter().map(|l| l * sigma).collect();
                // sphere test at a *loose* point: β = 0 with its exact state
                let beta0 = vec![0.0; p];
                let (loss0, grad0) = prob.loss_grad(&beta0);
                let mut h0 = vec![0.0; prob.n()];
                prob.family.h_loss(&vec![0.0; prob.n()], &prob.y, &mut h0);
                let mags = abs_sorted_desc(&grad0);
                let g = duality_gap(
                    prob.family,
                    &prob.y,
                    &h0,
                    loss0,
                    sl1_norm(&beta0, &lam),
                    &mags,
                    &lam,
                );
                let mut scr = SafeScreener::new(&prob, ParConfig::serial());
                scr.set_reference(&h0, &grad0);
                let radius = SafeScreener::radius(g.gap, prob.family.hessian_bound())
                    .expect("gaussian has a curvature bound");
                let lam_min = *lam.last().unwrap();
                for &j in &support {
                    ensure(
                        scr.keeps(grad0[j].abs(), j, g.scale, radius, lam_min),
                        format!(
                            "active predictor {j} discarded (|g|={}, radius={radius}, s={}, λ_min={lam_min})",
                            grad0[j].abs(),
                            g.scale
                        ),
                    )?;
                }
                // and the same soundness through the reference *bounds*
                let d = scr.ref_distance(&h0); // 0 here, but exercises the path
                for &j in &support {
                    ensure(
                        scr.keeps(scr.mag_bound(j, d), j, g.scale, radius, lam_min),
                        format!("active predictor {j} discarded via bounds"),
                    )?;
                }
                Ok(())
            },
        );
    }

    #[test]
    fn zero_gap_discards_only_below_lambda_min() {
        // At the optimum (gap 0, radius 0), the test reduces to
        // |x_jᵀθ*| < λ_min — which Theorem 1 proves is impossible for
        // active coordinates; inactive small-correlation ones go.
        let prob = gaussian_problem(9, 30, 10, 2, false);
        let lam_base = bh_sequence(10, 0.1);
        let (_, grad0) = prob.loss_grad(&vec![0.0; 10]);
        let smax = sigma_max(&grad0, &lam_base);
        let lam: Vec<f64> = lam_base.iter().map(|l| l * smax).collect();
        // At σ_max, β* = 0 and θ* = −h(0)/1; every |g_j| < λ_min is
        // certifiably zero (they all are — β* = 0 — but the test may
        // only discard the sub-λ_min ones).
        let scr = {
            let mut s = SafeScreener::new(&prob, ParConfig::serial());
            let mut h0 = vec![0.0; prob.n()];
            prob.family.h_loss(&vec![0.0; prob.n()], &prob.y, &mut h0);
            s.set_reference(&h0, &grad0);
            s
        };
        let lam_min = *lam.last().unwrap();
        for j in 0..10 {
            let kept = scr.keeps(grad0[j].abs(), j, 1.0, 0.0, lam_min);
            assert_eq!(
                kept,
                grad0[j].abs() >= lam_min,
                "zero-radius test must threshold exactly at λ_min"
            );
        }
    }
}

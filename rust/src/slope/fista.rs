//! FISTA (Beck & Teboulle 2009) with backtracking on the *reduced*
//! (screened) SLOPE problem — the paper's solver of record (§3.1 uses the
//! accelerated proximal gradient implementation of the R `SLOPE` package).
//!
//! The reduced problem keeps only the screened coefficient set `E` (a set
//! of flattened coefficient indices, see [`crate::slope::family::Problem`])
//! and the first `card E` entries of the scaled penalty vector — valid
//! because a vector supported on `E` puts its largest magnitudes against
//! the largest weights of λ.

use std::sync::Arc;

use crate::linalg::ops::inf_norm;
use crate::linalg::packed::{PackedDesign, PackedSet};
use crate::linalg::ParConfig;
use crate::slope::cancel::CancelToken;
use crate::slope::family::Problem;
use crate::obs::registry as obsreg;
use crate::slope::prox::{prox_sorted_l1_into, ProxWorkspace};
use crate::slope::sorted::sl1_norm;

/// Solver configuration.
#[derive(Clone, Debug)]
pub struct FistaConfig {
    /// Iteration cap.
    pub max_iter: usize,
    /// Convergence tolerance on the ℓ∞ norm of the gradient mapping,
    /// relative to `max(1, ‖β‖∞)`.
    pub tol: f64,
    /// When set, the displacement criterion alone is not trusted: on
    /// hitting it, the solver additionally verifies the Theorem-1 KKT
    /// conditions at the iterate to this absolute tolerance, and keeps
    /// iterating (with a tightened displacement tolerance) until they
    /// hold. This is what makes the path's violation counts (Fig. 3)
    /// solver-noise free.
    pub kkt_tol_abs: Option<f64>,
    /// Gap-certified stopping: when set, hitting the displacement
    /// criterion additionally evaluates the duality gap of the *reduced*
    /// problem at the iterate (see [`crate::slope::dual`]) and the solve
    /// only converges once `gap ≤ gap_tol_abs` — and, if `kkt_tol_abs`
    /// is also set, the KKT certificate holds too. The η cache makes
    /// this cost exactly what the KKT mode pays: one reduced `X_Eᵀh`
    /// product per check, no extra design product for η. The certified
    /// gap is reported in [`FistaResult::gap`].
    pub gap_tol_abs: Option<f64>,
    /// Cooperative cancellation: when set, the solver polls the token at
    /// the top of every iteration and exits *non-converged* once it
    /// fires. A fired token never interrupts mid-iteration arithmetic, so
    /// the returned partial iterate is always internally consistent
    /// (β, η(β) and the reported loss agree).
    pub cancel: Option<CancelToken>,
}

impl Default for FistaConfig {
    fn default() -> Self {
        Self { max_iter: 10_000, tol: 1e-7, kkt_tol_abs: None, gap_tol_abs: None, cancel: None }
    }
}

/// Result of a reduced solve.
#[derive(Clone, Debug)]
pub struct FistaResult {
    /// Solution over the reduced coefficient set (aligned with `E`).
    pub beta: Vec<f64>,
    /// Smooth loss `f` at the solution.
    pub loss: f64,
    /// Total objective `f + σJ`.
    pub objective: f64,
    /// Iterations performed.
    pub iterations: usize,
    /// Whether the tolerance was met before `max_iter`.
    pub converged: bool,
    /// Linear predictor `η = X_E β_E` at the solution (length `n·m`,
    /// a direct kernel product — not the extrapolation cache). The path
    /// driver's KKT sweep starts from this instead of recomputing it.
    pub eta: Vec<f64>,
    /// Most recently evaluated duality gap of the reduced problem
    /// (`None` unless the gap-certified mode ran a check). On a
    /// converged gap-mode solve this is the certificate itself.
    pub gap: Option<f64>,
}

/// The reduced view of a [`Problem`] restricted to coefficient set `E`:
/// per-class column lists so `η` and gradients touch only screened columns.
///
/// Two interchangeable kernel engines back it:
///
/// * **gather** ([`Reduced::new`]) — `gemv_subset`/`gemv_t_subset` chase
///   the column list through the full design on every call;
/// * **packed** ([`Reduced::packed`]) — the screened columns are
///   materialized once into a contiguous [`PackedDesign`] slab per class,
///   and the inner loop streams that instead (DESIGN.md §5). On dense
///   designs the two engines are bitwise interchangeable; sparse designs
///   agree to rounding. [`Reduced::append`] widens the set in place when
///   the KKT safeguard admits violators — packed slabs grow by appending
///   only the new columns, never re-packing.
///
/// Gather/scatter scratch is a *per-call* buffer the caller owns (see
/// [`Reduced::make_scratch`]) — the hot FISTA loop still performs zero
/// allocations per iteration, and `Reduced` itself is `Sync`, so a shared
/// reference can cross the scoped threads of the parallel backend.
pub struct Reduced<'a> {
    prob: &'a Problem,
    /// Flattened coefficient indices in `E` (ascending).
    pub coefs: Vec<usize>,
    /// For each class, the design columns present in `E`.
    cols_per_class: Vec<Vec<usize>>,
    /// For each class, the positions into the reduced vector of the
    /// entries of that class (parallel to `cols_per_class[class]`).
    pos_per_class: Vec<Vec<usize>>,
    /// Packed engine: one contiguous slab per class. `None` = gather.
    /// `Arc` so a [`crate::linalg::packed::PackCache`] can share slabs
    /// across fits; [`Reduced::append`] copies-on-write via `make_mut`.
    packs: Option<Vec<Arc<PackedDesign>>>,
    /// Largest per-class slice — the scratch size `eta`/`gradient` need.
    max_slice: usize,
    /// Thread budget for the subset kernels.
    par: ParConfig,
}

/// Per-class `(columns, reduced positions)` split of an ascending
/// flattened coefficient list: coefficient `c` is class `c / p`, design
/// column `c % p`.
fn class_split(coefs: &[usize], p: usize, m: usize) -> (Vec<Vec<usize>>, Vec<Vec<usize>>) {
    let mut cols_per_class: Vec<Vec<usize>> = vec![Vec::new(); m];
    let mut pos_per_class: Vec<Vec<usize>> = vec![Vec::new(); m];
    for (i, &c) in coefs.iter().enumerate() {
        debug_assert!(c < p * m);
        cols_per_class[c / p].push(c % p);
        pos_per_class[c / p].push(i);
    }
    (cols_per_class, pos_per_class)
}

impl<'a> Reduced<'a> {
    /// Build the reduced view with the gather engine. `coefs` must be
    /// ascending and in range. The kernel thread budget defaults to the
    /// process-wide setting; override it with [`Reduced::with_par`].
    pub fn new(prob: &'a Problem, coefs: Vec<usize>) -> Self {
        debug_assert!(coefs.windows(2).all(|w| w[0] < w[1]), "coefs must be ascending");
        let (cols_per_class, pos_per_class) =
            class_split(&coefs, prob.p(), prob.family.n_classes());
        let max_slice = cols_per_class.iter().map(Vec::len).max().unwrap_or(0);
        Self {
            prob,
            coefs,
            cols_per_class,
            pos_per_class,
            packs: None,
            max_slice,
            par: ParConfig::default(),
        }
    }

    /// Builder: set the kernel thread budget.
    pub fn with_par(mut self, par: ParConfig) -> Self {
        self.par = par;
        self
    }

    /// Builder: switch to the packed engine, materializing each class's
    /// screened columns into a contiguous slab (one `O(n·|E|)` pass,
    /// parallel under the configured budget). Call after
    /// [`Reduced::with_par`] so packing itself runs parallel.
    pub fn packed(mut self) -> Self {
        if self.packs.is_none() {
            self.packs = Some(
                self.cols_per_class
                    .iter()
                    .map(|cols| Arc::new(PackedDesign::pack(&self.prob.x, cols, self.par)))
                    .collect(),
            );
        }
        self
    }

    /// Build a packed reduced view by adopting the slabs of a cached
    /// [`PackedSet`] (same coefficient set, packed by an earlier fit) —
    /// the warm path that skips packing entirely.
    pub fn from_cached(prob: &'a Problem, set: &PackedSet, par: ParConfig) -> Self {
        let coefs = set.coefs.clone();
        debug_assert!(coefs.windows(2).all(|w| w[0] < w[1]), "coefs must be ascending");
        let (cols_per_class, pos_per_class) =
            class_split(&coefs, prob.p(), prob.family.n_classes());
        debug_assert_eq!(set.packs.len(), cols_per_class.len());
        debug_assert!(set
            .packs
            .iter()
            .zip(&cols_per_class)
            .all(|(pack, cols)| pack.sorted_cols() == *cols));
        let max_slice = cols_per_class.iter().map(Vec::len).max().unwrap_or(0);
        Self {
            prob,
            coefs,
            cols_per_class,
            pos_per_class,
            packs: Some(set.packs.clone()),
            max_slice,
            par,
        }
    }

    /// True when the packed engine backs this view.
    pub fn is_packed(&self) -> bool {
        self.packs.is_some()
    }

    /// Widen the reduced set by `extra` (ascending flattened coefficient
    /// indices, disjoint from the current set) — the KKT safeguard loop's
    /// violator admission. Packed slabs grow incrementally (only the new
    /// columns are materialized; shared slabs copy-on-write), and the
    /// position bookkeeping is rebuilt so `coefs` stays ascending.
    pub fn append(&mut self, extra: &[usize]) {
        if extra.is_empty() {
            return;
        }
        debug_assert!(extra.windows(2).all(|w| w[0] < w[1]), "extra must be ascending");
        // Disjointness matters: appending an already-packed column would
        // duplicate a slab slot. (The merge itself tolerates overlap.)
        debug_assert!(
            crate::slope::path::intersect_sorted(&self.coefs, extra).is_empty(),
            "extra must be disjoint from the current set"
        );
        let p = self.prob.p();
        let m = self.prob.family.n_classes();
        self.coefs = crate::slope::path::union_sorted(&self.coefs, extra);
        if let Some(packs) = &mut self.packs {
            let (extra_cols, _) = class_split(extra, p, m);
            for (pack, cols) in packs.iter_mut().zip(&extra_cols) {
                if !cols.is_empty() {
                    Arc::make_mut(pack).append(&self.prob.x, cols, self.par);
                }
            }
        }
        let (cols_per_class, pos_per_class) = class_split(&self.coefs, p, m);
        self.cols_per_class = cols_per_class;
        self.pos_per_class = pos_per_class;
        self.max_slice = self.cols_per_class.iter().map(Vec::len).max().unwrap_or(0);
    }

    /// Snapshot the packed slabs for a
    /// [`crate::linalg::packed::PackCache`] (cheap: `Arc` clones), or
    /// `None` on the gather engine.
    pub fn packed_set(&self) -> Option<Arc<PackedSet>> {
        self.packs.as_ref().map(|packs| {
            Arc::new(PackedSet { coefs: self.coefs.clone(), packs: packs.clone() })
        })
    }

    /// Number of reduced coefficients.
    pub fn len(&self) -> usize {
        self.coefs.len()
    }

    /// True when the reduced set is empty.
    pub fn is_empty(&self) -> bool {
        self.coefs.is_empty()
    }

    /// Allocate a gather/scatter scratch buffer for [`Reduced::eta`] /
    /// [`Reduced::gradient`]. One per solve, reused every iteration.
    pub fn make_scratch(&self) -> Vec<f64> {
        vec![0.0; self.max_slice]
    }

    /// `η = X_E β_E` (class-major, length `n·m`). Allocation-free given a
    /// [`Reduced::make_scratch`] buffer. Single-response packed views
    /// stream the slab directly — no gather at all: positions are the
    /// identity when there is one class.
    pub fn eta(&self, beta: &[f64], eta: &mut [f64], scratch: &mut [f64]) {
        let n = self.prob.n();
        let m = self.prob.family.n_classes();
        debug_assert_eq!(beta.len(), self.len());
        debug_assert_eq!(eta.len(), n * m);
        debug_assert!(scratch.len() >= self.max_slice);
        if m == 1 {
            if let Some(packs) = &self.packs {
                packs[0].gemv_with(beta, eta, self.par);
                return;
            }
        }
        for (l, cols) in self.cols_per_class.iter().enumerate() {
            let sub = &mut scratch[..cols.len()];
            for (s, &pos) in sub.iter_mut().zip(&self.pos_per_class[l]) {
                *s = beta[pos];
            }
            let out = &mut eta[l * n..(l + 1) * n];
            match &self.packs {
                Some(packs) => packs[l].gemv_with(sub, out, self.par),
                None => self.prob.x.gemv_subset_with(cols, sub, out, self.par),
            }
        }
    }

    /// Reduced gradient `X_Eᵀ h` (aligned with `coefs`). Allocation-free
    /// given a [`Reduced::make_scratch`] buffer; single-response packed
    /// views write straight into `grad`.
    pub fn gradient(&self, h: &[f64], grad: &mut [f64], scratch: &mut [f64]) {
        let n = self.prob.n();
        debug_assert_eq!(grad.len(), self.len());
        debug_assert!(scratch.len() >= self.max_slice);
        if self.prob.family.n_classes() == 1 {
            if let Some(packs) = &self.packs {
                packs[0].gemv_t_with(h, grad, self.par);
                return;
            }
        }
        for (l, cols) in self.cols_per_class.iter().enumerate() {
            if cols.is_empty() {
                continue;
            }
            let out = &mut scratch[..cols.len()];
            match &self.packs {
                Some(packs) => packs[l].gemv_t_with(&h[l * n..(l + 1) * n], out, self.par),
                None => {
                    self.prob
                        .x
                        .gemv_t_subset_with(cols, &h[l * n..(l + 1) * n], out, self.par)
                }
            }
            for (o, &pos) in out.iter().zip(&self.pos_per_class[l]) {
                grad[pos] = *o;
            }
        }
    }

    /// Estimate `‖X_E‖₂²` by a few power iterations (tight FISTA step
    /// initialization; the Frobenius bound is far too loose for large `E`).
    pub fn spectral_sq_estimate(&self, iters: usize) -> f64 {
        let k = self.len();
        if k == 0 {
            return 1.0;
        }
        let n = self.prob.n();
        let m = self.prob.family.n_classes();
        let mut v: Vec<f64> = (0..k).map(|i| 1.0 + (i % 7) as f64 * 0.1).collect();
        let mut eta = vec![0.0; n * m];
        let mut w = vec![0.0; k];
        let mut scratch = self.make_scratch();
        let mut est = 1.0;
        for _ in 0..iters {
            self.eta(&v, &mut eta, &mut scratch);
            self.gradient(&eta, &mut w, &mut scratch);
            let norm = w.iter().map(|x| x * x).sum::<f64>().sqrt();
            if norm < 1e-300 {
                return 1.0;
            }
            est = norm;
            for (vi, wi) in v.iter_mut().zip(&w) {
                *vi = wi / norm;
            }
        }
        // ‖XᵀX v‖ with unit v approximates the top eigenvalue of XᵀX.
        est.max(1e-12)
    }

    /// Scatter a reduced solution back into a full coefficient vector.
    pub fn scatter(&self, beta: &[f64], full: &mut [f64]) {
        full.fill(0.0);
        for (i, &c) in self.coefs.iter().enumerate() {
            full[c] = beta[i];
        }
    }
}

/// Solve the reduced SLOPE problem
/// `min f(β_E) + Σ_j σλ_j |β_E|_(j)` with FISTA + backtracking.
///
/// `lambda_scaled` must already include the σ factor and have length ≥
/// `reduced.len()`; `warm` (if given) seeds the iteration.
pub fn solve(
    reduced: &Reduced<'_>,
    lambda_scaled: &[f64],
    warm: Option<&[f64]>,
    cfg: &FistaConfig,
) -> FistaResult {
    let k = reduced.len();
    let prob = reduced.prob;
    let n = prob.n();
    let m = prob.family.n_classes();
    let lam = &lambda_scaled[..k];

    if k == 0 {
        let eta = vec![0.0; n * m];
        let mut h = vec![0.0; n * m];
        let loss = prob.family.h_loss(&eta, &prob.y, &mut h);
        return FistaResult {
            beta: Vec::new(),
            loss,
            objective: loss,
            iterations: 0,
            converged: true,
            eta,
            // An empty reduced problem has a single feasible point — its
            // own optimum — so the certified gap is identically zero.
            gap: cfg.gap_tol_abs.map(|_| 0.0),
        };
    }

    obsreg::FISTA_SOLVES.inc();
    // Fault-injection hook (chaos harness): one relaxed load when no plan
    // is armed. May sleep or panic per the armed plan; `corrupt_grad`
    // poisons this solve's first gradient below.
    let mut poison_grad = crate::fault::on_solve().corrupt_grad;
    let mut beta: Vec<f64> = match warm {
        Some(w) => {
            debug_assert_eq!(w.len(), k);
            w.to_vec()
        }
        None => vec![0.0; k],
    };
    let mut z = beta.clone();
    let mut t = 1.0f64;

    // Step-size initialization: curvature bound × spectral estimate.
    let spec = reduced.spectral_sq_estimate(12);
    let mut big_l = match prob.family.hessian_bound() {
        Some(b) => b * spec,
        None => spec, // Poisson: heuristic start, backtracking corrects
    }
    .max(1e-10);

    // η caches: the linear predictor is linear in β, so η at the
    // extrapolated point follows the same momentum recurrence as z itself
    // — `η(z⁺) = η(cand) + coef·(η(cand) − η(β))`. That replaces one of
    // the two design-matrix products each FISTA iteration used to pay
    // (for the Gaussian family this is exactly a cached residual
    // `r = η − y`, maintained incrementally through `h`). Rounding does
    // not compound: `eta_beta` and `eta_cand` are direct kernel products
    // every iteration, so `eta_z` is always one extrapolation step away
    // from fresh values — exactly like `z` itself.
    let mut scratch = reduced.make_scratch();
    let mut eta_z = vec![0.0; n * m];
    let mut eta_cand = vec![0.0; n * m];
    let mut h = vec![0.0; n * m];
    let mut grad = vec![0.0; k];
    let mut cand = vec![0.0; k];
    let mut step = vec![0.0; k];
    let mut ws = ProxWorkspace::new(k);
    let mut lam_over_l = vec![0.0; k];

    reduced.eta(&z, &mut eta_z, &mut scratch);
    let mut eta_beta = eta_z.clone(); // z == β at entry

    let mut iterations = 0;
    let mut converged = false;
    let mut tol_eff = cfg.tol;
    let mut last_gap: Option<f64> = None;
    // Sort scratch for the gap certificate's |∇| magnitudes — allocated
    // once, so the certificate checks stay off the allocator too.
    let mut mag_buf: Vec<f64> = Vec::with_capacity(if cfg.gap_tol_abs.is_some() { k } else { 0 });

    let mut cancelled = false;
    let mut numeric_abort = false;
    for iter in 0..cfg.max_iter {
        // Cooperative cancellation: poll between iterations so a fired
        // token never leaves β/η(β) mid-update.
        if let Some(tok) = cfg.cancel.as_ref() {
            if tok.is_cancelled() {
                cancelled = true;
                break;
            }
        }
        iterations = iter + 1;
        obsreg::FISTA_ITERATIONS.inc();
        // Gradient at the extrapolated point z.
        let loss_z = prob.family.h_loss(&eta_z, &prob.y, &mut h);
        reduced.gradient(&h, &mut grad, &mut scratch);
        if poison_grad {
            poison_grad = false;
            grad[0] = f64::NAN;
        }

        // Backtracking line search on L.
        let mut loss_cand;
        loop {
            let inv_l = 1.0 / big_l;
            for i in 0..k {
                step[i] = z[i] - grad[i] * inv_l;
                lam_over_l[i] = lam[i] * inv_l;
            }
            obsreg::FISTA_PROX_CALLS.inc();
            prox_sorted_l1_into(&step, &lam_over_l, &mut ws, &mut cand);
            reduced.eta(&cand, &mut eta_cand, &mut scratch);
            loss_cand = prob.family.h_loss(&eta_cand, &prob.y, &mut h);
            // Non-finite loss (NaN gradient, overflow): no amount of
            // backtracking recovers, so stop searching immediately — the
            // outer bail below exits the solve non-converged.
            if !loss_cand.is_finite() {
                break;
            }
            // Majorization check: f(cand) ≤ f(z) + ⟨∇f(z), cand−z⟩ + L/2‖cand−z‖².
            let mut lin = 0.0;
            let mut sq = 0.0;
            for i in 0..k {
                let d = cand[i] - z[i];
                lin += grad[i] * d;
                sq += d * d;
            }
            if loss_cand <= loss_z + lin + 0.5 * big_l * sq + 1e-12 * loss_z.abs().max(1.0) {
                break;
            }
            obsreg::FISTA_BACKTRACKS.inc();
            big_l *= 2.0;
            if big_l > 1e18 {
                break; // numerical wall; accept and let KKT checks catch it
            }
        }

        // Poisoned arithmetic bail: exit *before* the momentum update so
        // β/η(β) keep their last finite values and the returned partial
        // result stays coherent. The caller (path safeguard, degradation
        // ladder) sees `converged: false` and recovers.
        if !loss_z.is_finite() || !loss_cand.is_finite() {
            numeric_abort = true;
            break;
        }

        // Convergence: the proximal-gradient step displacement at z,
        // relative to the solution scale (a scaled gradient-mapping norm).
        let mut disp = 0.0f64;
        for i in 0..k {
            disp = disp.max((z[i] - cand[i]).abs());
        }
        let scale = inf_norm(&cand).max(1.0);

        // Adaptive restart (O'Donoghue & Candès 2015, gradient scheme):
        // when the momentum direction opposes the proximal-gradient step,
        // kill the momentum. Restores monotone, linear-rate convergence on
        // strongly convex segments — essential for the high-precision
        // solves the KKT-verified mode demands.
        let mut restart_dot = 0.0;
        for i in 0..k {
            restart_dot += (z[i] - cand[i]) * (cand[i] - beta[i]);
        }
        if restart_dot > 0.0 {
            t = 1.0;
        }

        // Momentum update, with η carried along the same recurrence.
        let t_next = 0.5 * (1.0 + (1.0 + 4.0 * t * t).sqrt());
        let coef = (t - 1.0) / t_next;
        for i in 0..k {
            let prev = beta[i];
            beta[i] = cand[i];
            z[i] = cand[i] + coef * (cand[i] - prev);
        }
        for i in 0..n * m {
            let e_prev = eta_beta[i];
            let e_cand = eta_cand[i];
            eta_z[i] = e_cand + coef * (e_cand - e_prev);
            eta_beta[i] = e_cand; // β := cand, so η(β) := η(cand) (a fresh product)
        }
        t = t_next;

        if disp <= tol_eff * scale {
            if cfg.kkt_tol_abs.is_none() && cfg.gap_tol_abs.is_none() {
                converged = true;
                break;
            }
            // Verify true certificates at beta (not z). β = cand here, so
            // `h` — just computed from the fresh η(cand) in the line
            // search — already holds the working residual at β; only the
            // reduced X_Eᵀh product is paid, no extra η product.
            reduced.gradient(&h, &mut grad, &mut scratch);
            let mut certified = true;
            if let Some(gap_tol) = cfg.gap_tol_abs {
                mag_buf.clear();
                mag_buf.extend(grad.iter().map(|g| g.abs()));
                mag_buf.sort_unstable_by(|a, b| b.total_cmp(a));
                let gr = crate::slope::dual::duality_gap(
                    prob.family,
                    &prob.y,
                    &h,
                    loss_cand,
                    sl1_norm(&beta, lam),
                    &mag_buf,
                    lam,
                );
                last_gap = Some(gr.gap);
                certified &= gr.gap <= gap_tol;
            }
            if certified {
                if let Some(kkt_tol) = cfg.kkt_tol_abs {
                    certified &=
                        crate::slope::subdiff::kkt_optimal(&beta, &grad, lam, kkt_tol);
                }
            }
            if certified {
                converged = true;
                break;
            }
            // Not there yet: demand more progress before checking again
            // (bounded so we terminate at max_iter).
            tol_eff = (tol_eff * 0.25).max(1e-16);
        }
        // Mild step-size recovery so one conservative backtrack does not
        // slow the whole path.
        big_l *= 0.97;
        let _ = loss_cand;
    }

    // Genuine iteration-budget exhaustion (not cancellation, not a
    // poisoned-arithmetic bail) is the signal the degradation ladder and
    // the profile subcommand watch.
    if !converged && !cancelled && !numeric_abort {
        obsreg::FISTA_NONCONVERGED.inc();
    }

    // Final loss/objective at beta. `eta_beta` is η(β) from a direct
    // kernel product at every exit (warm entry included), so no closing
    // recomputation is needed.
    let loss = prob.family.h_loss(&eta_beta, &prob.y, &mut h);
    let objective = loss + sl1_norm(&beta, lam);
    FistaResult { beta, loss, objective, iterations, converged, eta: eta_beta, gap: last_gap }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{Design, Mat};
    use crate::rng::Pcg64;
    use crate::slope::family::Family;
    use crate::slope::lambda::bh_sequence;
    use crate::slope::subdiff::kkt_optimal;

    fn random_problem(seed: u64, n: usize, p: usize, family: Family) -> Problem {
        let mut rng = Pcg64::new(seed);
        let mut x = Mat::zeros(n, p);
        for j in 0..p {
            for i in 0..n {
                x.set(i, j, rng.normal());
            }
        }
        x.standardize(true, true);
        let beta_true: Vec<f64> = (0..p).map(|j| if j < 3 { 2.0 } else { 0.0 }).collect();
        let mut eta = vec![0.0; n];
        x.gemv(&beta_true, &mut eta);
        let y: Vec<f64> = match family {
            Family::Gaussian => eta.iter().map(|e| e + 0.1 * rng.normal()).collect(),
            Family::Binomial => eta
                .iter()
                .map(|&e| if rng.bernoulli(crate::slope::family::sigmoid(e)) { 1.0 } else { 0.0 })
                .collect(),
            Family::Poisson => eta.iter().map(|&e| rng.poisson(e.clamp(-3.0, 3.0).exp()) as f64).collect(),
            Family::Multinomial { classes } => {
                (0..n).map(|i| (i % classes) as f64).collect()
            }
        };
        Problem::new(Design::Dense(x), y, family)
    }

    fn full_reduced(prob: &Problem) -> Reduced<'_> {
        Reduced::new(prob, (0..prob.p_total()).collect())
    }

    #[test]
    fn solves_to_kkt_optimality_gaussian() {
        let prob = random_problem(1, 40, 12, Family::Gaussian);
        let lam: Vec<f64> = bh_sequence(12, 0.1).iter().map(|l| l * 0.05).collect();
        let red = full_reduced(&prob);
        let res = solve(&red, &lam, None, &FistaConfig { max_iter: 20_000, tol: 1e-10, ..Default::default() });
        assert!(res.converged);
        let (_, grad) = prob.loss_grad(&res.beta);
        assert!(
            kkt_optimal(&res.beta, &grad, &lam, 1e-5),
            "KKT violated; beta = {:?}",
            res.beta
        );
    }

    #[test]
    fn solves_to_kkt_optimality_binomial() {
        let prob = random_problem(2, 60, 10, Family::Binomial);
        let lam: Vec<f64> = bh_sequence(10, 0.1).iter().map(|l| l * 0.02).collect();
        let red = full_reduced(&prob);
        let res = solve(&red, &lam, None, &FistaConfig { max_iter: 30_000, tol: 1e-10, ..Default::default() });
        let (_, grad) = prob.loss_grad(&res.beta);
        assert!(kkt_optimal(&res.beta, &grad, &lam, 1e-5));
    }

    #[test]
    fn gap_certified_mode_converges_and_matches_kkt_mode() {
        let prob = random_problem(21, 40, 12, Family::Gaussian);
        let lam: Vec<f64> = bh_sequence(12, 0.1).iter().map(|l| l * 0.05).collect();
        let red = full_reduced(&prob);
        let gap_cfg = FistaConfig {
            max_iter: 30_000,
            tol: 1e-9,
            kkt_tol_abs: None,
            gap_tol_abs: Some(1e-10),
            cancel: None,
        };
        let gap_res = solve(&red, &lam, None, &gap_cfg);
        assert!(gap_res.converged, "gap mode must converge");
        let gap = gap_res.gap.expect("gap mode records its certificate");
        assert!(gap <= 1e-10 && gap >= -1e-12, "certified gap out of range: {gap}");
        let kkt_cfg = FistaConfig {
            max_iter: 30_000,
            tol: 1e-9,
            kkt_tol_abs: Some(1e-8),
            gap_tol_abs: None,
            cancel: None,
        };
        let kkt_res = solve(&red, &lam, None, &kkt_cfg);
        assert!(kkt_res.gap.is_none(), "kkt mode must not report a gap");
        for (a, b) in gap_res.beta.iter().zip(&kkt_res.beta) {
            assert!((a - b).abs() < 1e-5, "stopping modes disagree: {a} vs {b}");
        }
        // both certificates together are strictly tighter than either
        let both_cfg = FistaConfig {
            max_iter: 30_000,
            tol: 1e-9,
            kkt_tol_abs: Some(1e-8),
            gap_tol_abs: Some(1e-10),
            cancel: None,
        };
        let both = solve(&red, &lam, None, &both_cfg);
        assert!(both.converged);
        assert!(both.gap.unwrap() <= 1e-10);
        let (_, g) = prob.loss_grad(&both.beta);
        assert!(kkt_optimal(&both.beta, &g, &lam, 1e-8));
    }

    #[test]
    fn unreachable_gap_target_surfaces_as_nonconverged() {
        // A gap tolerance below the numeric floor must exhaust max_iter
        // and report converged = false, never a bogus certificate.
        let prob = random_problem(22, 30, 8, Family::Gaussian);
        let lam: Vec<f64> = bh_sequence(8, 0.1).iter().map(|l| l * 0.05).collect();
        let red = full_reduced(&prob);
        let cfg = FistaConfig {
            max_iter: 200,
            tol: 1e-9,
            kkt_tol_abs: None,
            gap_tol_abs: Some(-1.0), // below weak duality: unreachable
            cancel: None,
        };
        let res = solve(&red, &lam, None, &cfg);
        assert!(!res.converged);
        assert_eq!(res.iterations, 200);
    }

    #[test]
    fn large_penalty_gives_zero_solution() {
        let prob = random_problem(3, 30, 8, Family::Gaussian);
        let lam = vec![1e4; 8];
        let red = full_reduced(&prob);
        let res = solve(&red, &lam, None, &FistaConfig::default());
        assert!(res.beta.iter().all(|&b| b == 0.0));
    }

    #[test]
    fn reduced_subset_matches_full_when_support_inside() {
        // Solving on a superset of the support gives the same solution.
        let prob = random_problem(4, 50, 10, Family::Gaussian);
        let lam: Vec<f64> = bh_sequence(10, 0.1).iter().map(|l| l * 0.3).collect();
        let full = solve(
            &full_reduced(&prob),
            &lam,
            None,
            &FistaConfig { max_iter: 30_000, tol: 1e-11, ..Default::default() },
        );
        let support: Vec<usize> = full
            .beta
            .iter()
            .enumerate()
            .filter(|(_, &b)| b.abs() > 1e-9)
            .map(|(i, _)| i)
            .collect();
        assert!(!support.is_empty() && support.len() < 10, "need partial support");
        let red = Reduced::new(&prob, support.clone());
        let sub = solve(&red, &lam, None, &FistaConfig { max_iter: 30_000, tol: 1e-11, ..Default::default() });
        let mut scattered = vec![0.0; 10];
        red.scatter(&sub.beta, &mut scattered);
        for (a, b) in scattered.iter().zip(&full.beta) {
            assert!((a - b).abs() < 1e-5, "{scattered:?} vs {:?}", full.beta);
        }
    }

    #[test]
    fn warm_start_converges_faster() {
        let prob = random_problem(5, 50, 15, Family::Gaussian);
        let lam: Vec<f64> = bh_sequence(15, 0.1).iter().map(|l| l * 0.1).collect();
        let red = full_reduced(&prob);
        let cold = solve(&red, &lam, None, &FistaConfig { max_iter: 50_000, tol: 1e-9, ..Default::default() });
        let warm = solve(&red, &lam, Some(&cold.beta), &FistaConfig { max_iter: 50_000, tol: 1e-9, ..Default::default() });
        assert!(warm.iterations <= cold.iterations);
    }

    #[test]
    fn multinomial_reduced_roundtrip() {
        let prob = random_problem(6, 30, 6, Family::Multinomial { classes: 3 });
        let coefs = vec![0, 2, 7, 11, 13]; // spans all three classes
        let red = Reduced::new(&prob, coefs.clone());
        assert_eq!(red.len(), 5);
        let beta = vec![1.0, -2.0, 0.5, 0.25, -0.75];
        // eta/gradient consistency with the full problem via scatter:
        let mut full = vec![0.0; prob.p_total()];
        red.scatter(&beta, &mut full);
        let (_, g_full) = prob.loss_grad(&full);
        let n = prob.n();
        let m = prob.family.n_classes();
        let mut scratch = red.make_scratch();
        let mut eta = vec![0.0; n * m];
        red.eta(&beta, &mut eta, &mut scratch);
        let mut h = vec![0.0; n * m];
        prob.family.h_loss(&eta, &prob.y, &mut h);
        let mut g_red = vec![0.0; red.len()];
        red.gradient(&h, &mut g_red, &mut scratch);
        for (i, &c) in coefs.iter().enumerate() {
            assert!((g_red[i] - g_full[c]).abs() < 1e-10);
        }
    }

    #[test]
    fn packed_solve_matches_gather_solve_dense() {
        // On a dense design the packed engine's accumulation orders match
        // the gather kernels exactly, so whole solves are interchangeable.
        let prob = random_problem(11, 40, 14, Family::Gaussian);
        let lam: Vec<f64> = bh_sequence(14, 0.1).iter().map(|l| l * 0.05).collect();
        let coefs: Vec<usize> = (0..14).filter(|c| c % 3 != 1).collect();
        let cfg = FistaConfig { max_iter: 20_000, tol: 1e-9, ..Default::default() };
        let gather = solve(&Reduced::new(&prob, coefs.clone()), &lam, None, &cfg);
        let packed = solve(&Reduced::new(&prob, coefs.clone()).packed(), &lam, None, &cfg);
        assert_eq!(gather.iterations, packed.iterations);
        assert_eq!(gather.beta, packed.beta, "packed and gather solves must agree bitwise");
        assert_eq!(gather.eta, packed.eta);
    }

    #[test]
    fn append_widens_both_engines_identically() {
        let prob = random_problem(12, 30, 12, Family::Gaussian);
        let base: Vec<usize> = vec![1, 4, 7, 10];
        let extra: Vec<usize> = vec![0, 5, 11];
        let mut g = Reduced::new(&prob, base.clone());
        let mut p = Reduced::new(&prob, base.clone()).packed();
        g.append(&extra);
        p.append(&extra);
        assert_eq!(g.coefs, p.coefs);
        assert_eq!(g.coefs, vec![0, 1, 4, 5, 7, 10, 11]);
        assert_eq!(g.len(), 7);
        let beta: Vec<f64> = (0..7).map(|i| 0.3 * i as f64 - 1.0).collect();
        let mut eg = vec![0.0; prob.n()];
        let mut ep = vec![0.0; prob.n()];
        let mut sg = g.make_scratch();
        let mut sp = p.make_scratch();
        g.eta(&beta, &mut eg, &mut sg);
        p.eta(&beta, &mut ep, &mut sp);
        assert_eq!(eg, ep, "eta after append must match across engines");
        let h: Vec<f64> = (0..prob.n()).map(|i| (i as f64) * 0.1 - 1.5).collect();
        let mut gg = vec![0.0; 7];
        let mut gp = vec![0.0; 7];
        g.gradient(&h, &mut gg, &mut sg);
        p.gradient(&h, &mut gp, &mut sp);
        assert_eq!(gg, gp, "gradient after append must match across engines");
    }

    #[test]
    fn packed_set_round_trips_through_cache_adoption() {
        let prob = random_problem(13, 25, 10, Family::Gaussian);
        let coefs: Vec<usize> = vec![0, 3, 4, 8];
        let red = Reduced::new(&prob, coefs.clone()).packed();
        let set = red.packed_set().expect("packed view must snapshot");
        assert_eq!(set.coefs, coefs);
        let adopted = Reduced::from_cached(&prob, &set, crate::linalg::ParConfig::serial());
        assert!(adopted.is_packed());
        assert_eq!(adopted.coefs, coefs);
        let beta = vec![1.0, -0.5, 0.25, 2.0];
        let mut e1 = vec![0.0; prob.n()];
        let mut e2 = vec![0.0; prob.n()];
        let mut s1 = red.make_scratch();
        let mut s2 = adopted.make_scratch();
        red.eta(&beta, &mut e1, &mut s1);
        adopted.eta(&beta, &mut e2, &mut s2);
        assert_eq!(e1, e2);
        // gather views have no packed set to share
        assert!(Reduced::new(&prob, coefs).packed_set().is_none());
    }

    #[test]
    fn multinomial_packed_matches_gather() {
        let prob = random_problem(14, 30, 6, Family::Multinomial { classes: 3 });
        let coefs = vec![0usize, 2, 7, 11, 13]; // spans all three classes
        let g = Reduced::new(&prob, coefs.clone());
        let p = Reduced::new(&prob, coefs).packed();
        let beta = vec![1.0, -2.0, 0.5, 0.25, -0.75];
        let n = prob.n();
        let m = prob.family.n_classes();
        let (mut eg, mut ep) = (vec![0.0; n * m], vec![0.0; n * m]);
        let mut sg = g.make_scratch();
        let mut sp = p.make_scratch();
        g.eta(&beta, &mut eg, &mut sg);
        p.eta(&beta, &mut ep, &mut sp);
        assert_eq!(eg, ep);
        let h: Vec<f64> = (0..n * m).map(|i| (i as f64) * 0.05 - 1.0).collect();
        let (mut gg, mut gp) = (vec![0.0; 5], vec![0.0; 5]);
        g.gradient(&h, &mut gg, &mut sg);
        p.gradient(&h, &mut gp, &mut sp);
        assert_eq!(gg, gp);
    }

    #[test]
    fn reduced_is_sync() {
        // The parallel backend shares `&Reduced` across scoped threads;
        // the per-call scratch design (no RefCell) is what makes this hold.
        fn assert_sync<T: Sync>() {}
        assert_sync::<Reduced<'static>>();
    }

    #[test]
    fn result_eta_is_the_solution_eta() {
        let prob = random_problem(7, 30, 10, Family::Gaussian);
        let lam: Vec<f64> = bh_sequence(10, 0.1).iter().map(|l| l * 0.1).collect();
        let red = full_reduced(&prob);
        let res = solve(&red, &lam, None, &FistaConfig::default());
        let mut eta = vec![0.0; prob.n()];
        let mut scratch = red.make_scratch();
        red.eta(&res.beta, &mut eta, &mut scratch);
        assert_eq!(eta.len(), res.eta.len());
        for (a, b) in eta.iter().zip(&res.eta) {
            assert!((a - b).abs() < 1e-12, "{a} vs {b}");
        }
        // and the recorded loss is the loss of that eta
        let mut h = vec![0.0; prob.n()];
        let loss = prob.family.h_loss(&res.eta, &prob.y, &mut h);
        assert!((loss - res.loss).abs() < 1e-12);
    }

    #[test]
    fn spectral_estimate_close_to_frobenius_bound_for_rank1() {
        // Rank-1 matrix: spectral norm equals Frobenius norm.
        let x = Mat::from_rows(&[&[1.0, 2.0], &[2.0, 4.0]]);
        let prob = Problem::new(Design::Dense(x), vec![0.0, 0.0], Family::Gaussian);
        let red = full_reduced(&prob);
        let est = red.spectral_sq_estimate(30);
        // ‖X‖₂² = 25 for [[1,2],[2,4]]
        assert!((est - 25.0).abs() < 1e-6, "est={est}");
    }
}

//! # slope-screen
//!
//! A production-grade reproduction of *The Strong Screening Rule for SLOPE*
//! (Larsson, Bogdan & Wallin, NeurIPS 2020) as a three-layer
//! Rust + JAX + Pallas stack.
//!
//! The crate provides:
//!
//! * [`slope`] — the sorted-ℓ1 machinery: the prox operator, penalty
//!   sequences, the subdifferential/KKT conditions of Theorem 1, the
//!   screening rules (Algorithms 1–2), the FISTA solver and the
//!   regularization-path driver with the strong-set (Algorithm 3) and
//!   previous-set (Algorithm 4) strategies.
//! * [`runtime`] — the PJRT bridge that loads the AOT-compiled JAX/Pallas
//!   gradient artifacts (`artifacts/*.hlo.txt`) and evaluates full-design
//!   gradients on the screening/KKT hot path.
//! * [`coordinator`] — cross-validation and experiment orchestration over a
//!   worker pool.
//! * [`serve`] — a long-running, multi-threaded fit server with a
//!   fingerprinted warm-start cache and batched scheduling: the screening
//!   rule amortized across *requests*, not just across path steps.
//! * [`data`] — synthetic design generators and simulated stand-ins for the
//!   paper's real datasets, with export helpers so the stand-ins double as
//!   file fixtures.
//! * [`ingest`] — streaming dataset ingestion: dense CSV and sparse
//!   svmlight/libsvm readers with bounded-memory two-pass builders, strict
//!   typed validation and content fingerprinting (`fit --data file.csv`,
//!   serve's `dataset_from_file`).
//! * [`obs`] — the observability layer: a global counter/gauge registry
//!   over the hot seams (kernels, caches, solver, screening), an opt-in
//!   span/event tracer with a JSONL sink (`--trace`), and the trace
//!   profiler behind the `profile` subcommand (DESIGN.md §11).
//! * [`fault`] — the deterministic fault-injection registry behind the
//!   chaos test harness and `--fault-plan` (DESIGN.md §12); a single
//!   disabled branch in production.
//! * substrates built for the offline environment: [`rng`], [`linalg`],
//!   [`pool`], [`cli`], [`jsonio`], [`check`] and [`benchkit`].
//!
//! See `DESIGN.md` for the layer map, experiment index and the serve
//! protocol, and `EXPERIMENTS.md` for the recorded reproduction runs.

pub mod benchkit;
pub mod check;
pub mod cli;
pub mod coordinator;
pub mod data;
pub mod fault;
pub mod ingest;
pub mod jsonio;
pub mod linalg;
pub mod obs;
pub mod pool;
pub mod rng;
pub mod runtime;
pub mod serve;
pub mod slope;

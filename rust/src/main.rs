//! `slope-screen` — CLI for the Strong-Screening-Rule-for-SLOPE stack.
//!
//! Subcommands:
//!   fit     fit a SLOPE path on synthetic, simulated-real or file data
//!   cv      repeated k-fold cross-validation over the path
//!   export  write a simulated stand-in as a .csv/.svm ingest fixture
//!   info    show the AOT artifact manifest and PJRT platform
//!   serve   run the fit server (Unix socket, TCP or stdio transport)
//!   client  send newline-delimited JSON requests to a running server
//!   profile summarize a `--trace` JSONL file (self-time, events, counters)
//!
//! Examples:
//!   slope-screen fit --n 200 --p 5000 --rho 0.4 --family gaussian
//!   slope-screen fit --n 200 --p 5000 --trace /tmp/fit.jsonl
//!   slope-screen fit --n 200 --p 5000 --checkpoint /tmp/fit.ckpt --resume
//!   slope-screen serve --socket /tmp/slope-serve.sock --state-dir /var/lib/slope
//!   slope-screen profile /tmp/fit.jsonl
//!   slope-screen fit --dataset golub --screen previous
//!   slope-screen fit --data genes.csv --family binomial
//!   slope-screen fit --data dorothea.svm --family binomial --no-standardize
//!   slope-screen fit --n 100 --p 500 --grad-engine xla
//!   slope-screen cv --n 200 --p 1000 --folds 5 --repeats 2
//!   slope-screen export --dataset golub --out /tmp/standins
//!   slope-screen serve --socket /tmp/slope-serve.sock
//!   slope-screen serve --tcp 127.0.0.1:7878 --gather-window-ms 2
//!   slope-screen client --json '{"id":1,"op":"stats"}'

use slope_screen::cli::Args;
use slope_screen::coordinator::{cross_validate, CvConfig};
use slope_screen::data::real::RealDataset;
use slope_screen::data::synth::{BetaSpec, DesignKind, SyntheticSpec};
use slope_screen::rng::Pcg64;
use slope_screen::runtime::{ArtifactGradient, Engine, Manifest};
use slope_screen::slope::family::{Family, Problem};
use slope_screen::slope::lambda::{LambdaKind, PathConfig};
use slope_screen::slope::path::{
    fit_path, FullGradient, NativeGradient, PathOptions, Strategy,
};

fn main() {
    let parsed = Args::new("slope-screen: SLOPE paths with the strong screening rule")
        .opt("n", "200", "observations (synthetic data)")
        .opt("p", "1000", "predictors (synthetic data)")
        .opt("k", "20", "true support size (synthetic data)")
        .opt("rho", "0.0", "pairwise correlation (synthetic data)")
        .opt("design", "compound", "design kind: compound|chain|iid")
        .opt("family", "gaussian", "gaussian|binomial|poisson|multinomial")
        .opt("classes", "3", "classes for multinomial")
        .opt("dataset", "", "simulated real dataset (overrides synthetic): arcene|dorothea|gisette|golub|cpusmall|physician|zipcode")
        .opt("data", "", "fit/cv: ingest a dataset file (.csv dense, .svm/.svmlight sparse; overrides --dataset); --family/--classes set the response")
        .flag("no-standardize", "data: ingest columns as-is (file already in model coordinates)")
        .opt("out", ".", "export: output directory")
        .opt("lambda", "bh", "penalty shape: bh|oscar|lasso|gaussian-seq")
        .opt("q", "0.1", "BH/OSCAR parameter")
        .opt("path-length", "100", "number of path points")
        .opt("screen", "strong", "strategy: none|strong|previous|safe|hybrid")
        .opt("gap-tol", "0", "relative duality-gap tolerance for safe/hybrid screening (0 = library default; serve caps it at 1e-4)")
        .opt("grad-engine", "native", "full-gradient engine: native|xla")
        .opt("folds", "5", "cv folds")
        .opt("repeats", "1", "cv repeats")
        .opt("threads", "0", "worker/kernel threads (0 = all cores; fit: parallel linalg backend, cv/serve: pool size)")
        .opt("seed", "42", "rng seed")
        .flag("no-early-stop", "disable the path termination rules")
        .opt("socket", "/tmp/slope-serve.sock", "serve/client: unix socket path")
        .opt("tcp", "", "serve/client: TCP endpoint HOST:PORT (overrides --socket; serve announces the resolved address on stderr, so :0 picks a free port); client accepts a comma-separated list and fails over across it")
        .opt("queue", "64", "serve: admission-queue capacity (backpressure bound)")
        .opt("max-conns", "0", "serve: accept-time connection cap, both transports (0 = 1024); excess connections get a typed `overload` response and a close")
        .opt("gather-window-ms", "0", "serve: coalesce same-dataset fit_point/predict requests arriving within this window into one batched solve (0 = off; DESIGN.md §14)")
        .opt("max-batch", "32", "serve: most requests one gather window may coalesce (a full batch closes early)")
        .opt("fit-threads", "0", "serve: kernel threads per fit job (0 = threads split across the pool)")
        .opt("deadline-ms", "0", "fit/serve: per-fit deadline in milliseconds (0 = none); an expired fit is a typed `deadline` error, never a silent partial result")
        .opt("max-line-bytes", "16777216", "serve: byte cap on one NDJSON request line (oversized lines get a typed error)")
        .opt("shed-queue", "0", "serve: reject fit requests with a typed `overload` error once this many are parked (0 = blocking backpressure)")
        .opt("fault-plan", "", "fit/serve: arm deterministic fault injection (a JSON file path or inline JSON; see DESIGN.md §12 — chaos testing only)")
        .opt("checkpoint", "", "fit: write crash-safe path snapshots to this file (DESIGN.md §13)")
        .opt("checkpoint-every", "5", "fit: snapshot cadence in path steps (rescue events always snapshot)")
        .flag("resume", "fit: resume from --checkpoint if it holds a valid snapshot of this dataset (falls back to a cold start otherwise)")
        .opt("state-dir", "", "serve: journal dataset registrations, warm-start seeds and quarantine strikes here and restore them on boot")
        .opt("standby", "", "serve: start as a warm standby replicating from this primary (comma-separated HOST:PORT list, tried in rotation); writes are fenced until promotion (DESIGN.md §15)")
        .opt("promote-on-loss", "0", "serve: standby self-promotes after this many consecutive missed heartbeats (0 = only the explicit `promote` op promotes)")
        .opt("idle-timeout-ms", "300000", "serve: reap TCP connections idle this long (0 = never; replication subscribers are exempt)")
        .opt("json", "", "client: a single request line to send")
        .opt("trace", "", "fit/cv/serve: write a JSONL span/event trace to this path (read it back with `profile`)")
        .flag("stdio", "serve: speak NDJSON over stdin/stdout instead of a socket")
        .flag("no-cache", "serve: disable the warm-start/model cache")
        .parse();

    // An explicit --threads pins the process-wide kernel budget for
    // every parallel linalg call (the pools still size themselves from
    // their own flags).
    if parsed.provided("threads") {
        slope_screen::linalg::par::set_global_threads(parsed.usize("threads"));
    }

    let cmd = parsed
        .positional()
        .first()
        .cloned()
        .unwrap_or_else(|| "fit".to_string());
    // --trace turns the observability tracer on for the whole command;
    // disable() writes the closing registry snapshot and flushes.
    let trace = parsed.get("trace").to_string();
    if !trace.is_empty() {
        if let Err(e) = slope_screen::obs::trace::enable_file(std::path::Path::new(&trace)) {
            eprintln!("--trace {trace}: {e}");
            std::process::exit(1);
        }
    }
    match cmd.as_str() {
        "fit" => cmd_fit(&parsed),
        "cv" => cmd_cv(&parsed),
        "export" => cmd_export(&parsed),
        "info" => cmd_info(),
        "serve" => cmd_serve(&parsed),
        "client" => cmd_client(&parsed),
        "profile" => cmd_profile(&parsed),
        other => {
            eprintln!("unknown subcommand `{other}` (expected fit|cv|export|info|serve|client|profile)");
            std::process::exit(2);
        }
    }
    if !trace.is_empty() {
        slope_screen::obs::trace::disable();
        eprintln!("trace written to {trace}");
    }
}

/// Build the problem for `fit`/`cv`, plus a content fingerprint of the
/// dataset it came from. The fingerprint is stamped into checkpoints so
/// a snapshot can never be resumed against the wrong data: file data
/// uses ingest's streamed content hash, named stand-ins and synthetic
/// specs use a canonical-identity hash (deterministic generators — the
/// identity *is* the content).
fn build_problem(parsed: &slope_screen::cli::Parsed) -> (Problem, u64) {
    use slope_screen::ingest::{fnv1a, FNV_BASIS};
    let data = parsed.get("data");
    if !data.is_empty() {
        use slope_screen::ingest::{load_path, IngestOptions};
        let family = Family::parse(parsed.get("family"), parsed.usize("classes"))
            .unwrap_or_else(|e| panic!("--family: {e}"));
        let opts = IngestOptions::default()
            .with_family(family)
            .with_standardize(!parsed.bool("no-standardize"));
        let ing = load_path(std::path::Path::new(data), &opts)
            .unwrap_or_else(|e| panic!("--data {data}: {e}"));
        let prob = ing.problem;
        let nnz = match &prob.x {
            slope_screen::linalg::Design::Sparse(csc) => csc.nnz(),
            slope_screen::linalg::Design::Dense(m) => m.nrows() * m.ncols(),
        };
        println!(
            "ingested {data}: n={} p={} nnz={} family={} fingerprint={:016x}",
            prob.n(),
            prob.p(),
            nnz,
            prob.family.name(),
            ing.fingerprint
        );
        return (prob, ing.fingerprint);
    }
    let dataset = parsed.get("dataset");
    if !dataset.is_empty() {
        let ds = RealDataset::all()
            .into_iter()
            .find(|d| d.name() == dataset)
            .unwrap_or_else(|| panic!("unknown dataset {dataset}"));
        let prob = ds.load();
        println!(
            "dataset {} (simulated stand-in): n={} p={} family={}",
            ds.name(),
            prob.n(),
            prob.p(),
            prob.family.name()
        );
        let fp = fnv1a(FNV_BASIS, format!("real:{}", ds.name()).as_bytes());
        return (prob, fp);
    }
    let family = Family::parse(parsed.get("family"), parsed.usize("classes"))
        .unwrap_or_else(|e| panic!("--family: {e}"));
    let design = match parsed.get("design") {
        "compound" => DesignKind::Compound,
        "chain" => DesignKind::Chain,
        "iid" => DesignKind::Iid,
        d => panic!("unknown design {d}"),
    };
    let k = parsed.usize("k");
    let spec = SyntheticSpec {
        n: parsed.usize("n"),
        p: parsed.usize("p"),
        rho: parsed.f64("rho"),
        design,
        beta: match family {
            Family::Poisson => BetaSpec::Ladder { k, step: 1.0 / 40.0 },
            _ => BetaSpec::PlusMinus { k, scale: 2.0 },
        },
        family,
        noise_sd: 1.0,
        standardize: true,
    };
    let fp = fnv1a(
        FNV_BASIS,
        format!(
            "synth:n={},p={},k={},rho={},design={},family={},classes={},seed={}",
            spec.n,
            spec.p,
            k,
            spec.rho,
            parsed.get("design"),
            parsed.get("family"),
            parsed.usize("classes"),
            parsed.u64("seed"),
        )
        .as_bytes(),
    );
    (spec.generate(&mut Pcg64::new(parsed.u64("seed"))), fp)
}

fn build_opts(parsed: &slope_screen::cli::Parsed, prob: &Problem) -> PathOptions {
    let kind = match parsed.get("lambda") {
        "bh" => LambdaKind::Bh { q: parsed.f64("q") },
        "oscar" => LambdaKind::Oscar { q: parsed.f64("q") },
        "lasso" => LambdaKind::Lasso,
        "gaussian-seq" => LambdaKind::Gaussian { q: parsed.f64("q"), n: prob.n() },
        l => panic!("unknown lambda kind {l}"),
    };
    let mut cfg = PathConfig::new(kind);
    cfg.length = parsed.usize("path-length");
    if parsed.bool("no-early-stop") {
        cfg = cfg.without_early_stopping();
    }
    let strategy = match parsed.get("screen") {
        "none" => Strategy::NoScreening,
        "strong" => Strategy::StrongSet,
        "previous" => Strategy::PreviousSet,
        "safe" => Strategy::SafeOnly,
        "hybrid" => Strategy::GapHybrid,
        s => panic!("unknown strategy {s}"),
    };
    let mut opts = PathOptions::new(cfg).with_strategy(strategy);
    let gap_tol = parsed.f64("gap-tol");
    if gap_tol > 0.0 {
        opts = opts.with_gap_tol(gap_tol);
    }
    opts
}

/// Run the path fit, honoring `--checkpoint`/`--resume` when given: a
/// valid snapshot of *this* dataset continues bitwise-identically from
/// its recorded step; anything else (missing, corrupt, wrong data) logs
/// the typed error and starts cold — resumption is best-effort, the fit
/// itself never is.
fn run_fit(
    parsed: &slope_screen::cli::Parsed,
    prob: &Problem,
    opts: &PathOptions,
    evaluator: &dyn FullGradient,
    dataset_fp: u64,
) -> slope_screen::slope::path::PathFit {
    use slope_screen::slope::path::{fit_path_checkpointed, resume_path, CheckpointConfig};
    let ckpt = parsed.get("checkpoint");
    if ckpt.is_empty() {
        if parsed.bool("resume") {
            eprintln!("fit: --resume requires --checkpoint <path>");
            std::process::exit(2);
        }
        return fit_path(prob, opts, evaluator);
    }
    let cfg = CheckpointConfig {
        path: std::path::PathBuf::from(ckpt),
        every: parsed.usize("checkpoint-every"),
        dataset_fingerprint: dataset_fp,
    };
    if parsed.bool("resume") {
        match resume_path(prob, opts, evaluator, &cfg) {
            Ok((fit, start)) => {
                println!(
                    "resumed from checkpoint {} at path step {start}",
                    cfg.path.display()
                );
                return fit;
            }
            Err(e) => eprintln!("checkpoint: {e} (kind: {}); starting cold", e.kind()),
        }
    }
    fit_path_checkpointed(prob, opts, evaluator, None, &cfg)
}

fn cmd_fit(parsed: &slope_screen::cli::Parsed) {
    arm_fault_plan(parsed.get("fault-plan"));
    let (prob, dataset_fp) = build_problem(parsed);
    // --threads routes to the parallel backend (0 = process default).
    let mut opts = build_opts(parsed, &prob).with_threads(parsed.usize("threads"));
    let deadline_ms = parsed.u64("deadline-ms");
    if deadline_ms > 0 {
        opts = opts.with_cancel(
            slope_screen::slope::cancel::CancelToken::with_deadline_ms(deadline_ms),
        );
    }
    let use_xla = parsed.get("grad-engine") == "xla";

    let fit = if use_xla {
        let manifest = Manifest::load(&slope_screen::runtime::default_artifact_dir())
            .expect("artifact manifest");
        let grad = ArtifactGradient::new(&manifest, &prob).expect("artifact gradient");
        println!(
            "grad engine: {} bucket={:?} padding-overhead={:.2}x",
            grad.label(),
            grad.bucket(),
            grad.padding_overhead()
        );
        run_fit(parsed, &prob, &opts, &grad, dataset_fp)
    } else {
        run_fit(parsed, &prob, &opts, &NativeGradient(&prob), dataset_fp)
    };

    if fit.stopped_early == Some("cancelled") {
        eprintln!(
            "fit: deadline of {deadline_ms} ms expired after {} completed path steps; partial results are not reported",
            fit.steps.len()
        );
        std::process::exit(1);
    }

    println!(
        "path: {} steps (requested {}), strategy={}, wall={:.3}s{}",
        fit.steps.len(),
        opts.config.length,
        opts.strategy.name(),
        fit.wall_time,
        fit.stopped_early
            .map(|r| format!(", stopped early: {r}"))
            .unwrap_or_default()
    );
    println!("total violations: {}", fit.total_violations);
    println!("step  sigma      active  screened  fitted  viol  dev.ratio");
    for (i, s) in fit.steps.iter().enumerate() {
        println!(
            "{i:>4}  {:<9.4} {:>6}  {:>8}  {:>6}  {:>4}  {:>8.4}",
            s.sigma, s.n_active, s.n_screened_rule, s.n_fitted, s.violations, s.dev_ratio
        );
    }
    let (ts, tv, tk) = slope_screen::slope::path::phase_totals(&fit);
    println!("phase totals: screen={ts:.4}s solve={tv:.4}s kkt={tk:.4}s");
    println!("full-gradient sweeps (p-equivalents): {:.2}", fit.total_grad_sweeps);
    let degraded = fit.steps.iter().filter(|s| s.degraded_to.is_some()).count();
    if degraded > 0 {
        println!("degradation ladder: {degraded} step(s) rescued by a more conservative strategy");
    }
    if fit.steps.iter().any(|s| !s.solver_converged) {
        println!("warning: some inner solves hit max_iter before certifying — tighten --gap-tol/--path-length or raise fista.max_iter");
    }
}

fn cmd_cv(parsed: &slope_screen::cli::Parsed) {
    let (prob, _fp) = build_problem(parsed);
    let opts = build_opts(parsed, &prob);
    let cfg = CvConfig {
        folds: parsed.usize("folds"),
        repeats: parsed.usize("repeats"),
        threads: parsed.usize("threads"),
        seed: parsed.u64("seed"),
    };
    let res = cross_validate(&prob, &opts, &cfg);
    println!(
        "cv: {} folds × {} repeats in {:.3}s ({} fits)",
        cfg.folds,
        cfg.repeats,
        res.wall_time,
        res.folds.len()
    );
    println!(
        "best sigma = {:.5} (index {}), mean val deviance = {:.4} ± {:.4}",
        res.sigmas[res.best_index],
        res.best_index,
        res.mean_deviance[res.best_index],
        res.se_deviance[res.best_index]
    );
    let total_viol: usize = res.folds.iter().map(|f| f.violations).sum();
    println!("violations across folds: {total_viol}");
}

/// Write a simulated stand-in to disk in its natural ingest format
/// (sparse → `<name>.svm`, dense → `<name>.csv`), so the paper's file
/// workflows — `fit --data`, serve's `dataset_from_file`, the Table-3
/// bench's `file:` specs — can run against reproducible fixtures.
fn cmd_export(parsed: &slope_screen::cli::Parsed) {
    let name = parsed.get("dataset");
    if name.is_empty() {
        eprintln!("export: --dataset is required (arcene|dorothea|gisette|golub|cpusmall|physician|zipcode)");
        std::process::exit(2);
    }
    let ds = RealDataset::all()
        .into_iter()
        .find(|d| d.name() == name)
        .unwrap_or_else(|| panic!("unknown dataset {name}"));
    let dir = std::path::PathBuf::from(parsed.get("out"));
    if let Err(e) = std::fs::create_dir_all(&dir) {
        eprintln!("export: cannot create {}: {e}", dir.display());
        std::process::exit(1);
    }
    let prob = ds.load();
    let path = ds
        .export_problem(&prob, &dir)
        .unwrap_or_else(|e| panic!("export {}: {e}", ds.name()));
    println!(
        "wrote {} (n={} p={} family={}; ingest with `fit --data {} --family {} --no-standardize`)",
        path.display(),
        prob.n(),
        prob.p(),
        prob.family.name(),
        path.display(),
        match prob.family {
            Family::Gaussian => "gaussian",
            Family::Binomial => "binomial",
            Family::Poisson => "poisson",
            Family::Multinomial { .. } => "multinomial",
        }
    );
}

fn cmd_serve(parsed: &slope_screen::cli::Parsed) {
    use slope_screen::serve::{Server, ServerConfig};
    arm_fault_plan(parsed.get("fault-plan"));
    let cfg = ServerConfig {
        threads: parsed.usize("threads"),
        queue: parsed.usize("queue"),
        cache: !parsed.bool("no-cache"),
        fit_threads: parsed.usize("fit-threads"),
        gap_tol: parsed.f64("gap-tol"),
        max_line_bytes: parsed.usize("max-line-bytes"),
        deadline_ms: parsed.u64("deadline-ms"),
        shed_queue: parsed.usize("shed-queue"),
        state_dir: {
            let dir = parsed.get("state-dir");
            (!dir.is_empty()).then(|| std::path::PathBuf::from(dir))
        },
        max_conns: parsed.usize("max-conns"),
        gather_window_ms: parsed.u64("gather-window-ms"),
        max_batch: parsed.usize("max-batch"),
        standby: !parsed.get("standby").is_empty(),
        idle_timeout_ms: parsed.u64("idle-timeout-ms"),
    };
    let server = std::sync::Arc::new(Server::new(cfg));
    let standby = parsed.get("standby");
    if !standby.is_empty() {
        let primaries: Vec<String> = standby
            .split(',')
            .map(str::trim)
            .filter(|s| !s.is_empty())
            .map(String::from)
            .collect();
        if primaries.is_empty() {
            eprintln!("serve: --standby needs at least one HOST:PORT");
            std::process::exit(1);
        }
        eprintln!("slope-screen serve: standby replicating from {}", primaries.join(", "));
        // Detached: the loop exits on shutdown or promotion.
        let _ = slope_screen::serve::replica::spawn_standby(
            std::sync::Arc::clone(&server),
            slope_screen::serve::replica::StandbyConfig {
                primaries,
                promote_after_misses: parsed.u64("promote-on-loss"),
                seed: parsed.u64("seed"),
                ..Default::default()
            },
        );
    }
    if parsed.bool("stdio") {
        eprintln!("slope-screen serve: NDJSON on stdin/stdout (send {{\"op\":\"shutdown\"}} to stop)");
        let stdin = std::io::stdin();
        let stdout = std::io::stdout();
        if let Err(e) = server.serve_lines(stdin.lock(), stdout.lock()) {
            eprintln!("serve: transport error: {e}");
            std::process::exit(1);
        }
        eprintln!("slope-screen serve: shut down cleanly");
        return;
    }
    if !parsed.get("tcp").is_empty() {
        serve_tcp(parsed, &server);
        return;
    }
    serve_socket(parsed, &server);
}

/// Bind the TCP transport. The listener is bound *here*, before the
/// announcement, so `--tcp 127.0.0.1:0` prints the kernel-chosen port —
/// scripts (the CI smoke test among them) parse it from stderr.
#[cfg(unix)]
fn serve_tcp(parsed: &slope_screen::cli::Parsed, server: &std::sync::Arc<slope_screen::serve::Server>) {
    let addr = parsed.get("tcp");
    let listener = match std::net::TcpListener::bind(addr) {
        Ok(l) => l,
        Err(e) => {
            eprintln!("serve: cannot bind {addr}: {e}");
            std::process::exit(1);
        }
    };
    match listener.local_addr() {
        Ok(local) => eprintln!(
            "slope-screen serve: listening on {} ({} worker threads, queue {})",
            local,
            parsed.usize("threads"),
            parsed.usize("queue")
        ),
        Err(e) => {
            eprintln!("serve: cannot resolve local address of {addr}: {e}");
            std::process::exit(1);
        }
    }
    if let Err(e) = slope_screen::serve::net::serve_tcp_listener(server, listener) {
        eprintln!("serve: tcp error: {e}");
        std::process::exit(1);
    }
    eprintln!("slope-screen serve: shut down cleanly");
}

#[cfg(not(unix))]
fn serve_tcp(
    _parsed: &slope_screen::cli::Parsed,
    _server: &std::sync::Arc<slope_screen::serve::Server>,
) {
    eprintln!("serve: the poll(2) TCP transport is unix-only; use --stdio");
    std::process::exit(2);
}

/// Parse and install a `--fault-plan` (a JSON file path or inline JSON).
/// Chaos testing only; a plan that fails to parse is a startup error, not
/// a silently unarmed harness.
fn arm_fault_plan(spec: &str) {
    if spec.is_empty() {
        return;
    }
    let src = if std::path::Path::new(spec).exists() {
        std::fs::read_to_string(spec).unwrap_or_else(|e| {
            eprintln!("--fault-plan {spec}: {e}");
            std::process::exit(1);
        })
    } else {
        spec.to_string()
    };
    match slope_screen::fault::FaultPlan::parse_str(&src) {
        Ok(plan) => {
            eprintln!("FAULT INJECTION ARMED: {plan:?}");
            slope_screen::fault::install(plan);
        }
        Err(e) => {
            eprintln!("--fault-plan: {e}");
            std::process::exit(1);
        }
    }
}

#[cfg(unix)]
fn serve_socket(parsed: &slope_screen::cli::Parsed, server: &std::sync::Arc<slope_screen::serve::Server>) {
    let path = std::path::PathBuf::from(parsed.get("socket"));
    eprintln!(
        "slope-screen serve: listening on {} ({} worker threads, queue {})",
        path.display(),
        parsed.usize("threads"),
        parsed.usize("queue")
    );
    if let Err(e) = server.serve_unix(&path) {
        eprintln!("serve: socket error: {e}");
        std::process::exit(1);
    }
    eprintln!("slope-screen serve: shut down cleanly");
}

#[cfg(not(unix))]
fn serve_socket(
    _parsed: &slope_screen::cli::Parsed,
    _server: &std::sync::Arc<slope_screen::serve::Server>,
) {
    eprintln!("serve: unix-domain sockets are unavailable on this platform; use --stdio");
    std::process::exit(2);
}

/// Dial the serve endpoint the flags name: `--tcp HOST:PORT` on any
/// platform, else the `--socket` Unix path.
fn dial_client(parsed: &slope_screen::cli::Parsed) -> slope_screen::serve::client::Client {
    let tcp = parsed.get("tcp");
    if !tcp.is_empty() {
        return match slope_screen::serve::client::connect_tcp_with_retry(tcp, 20, 50) {
            Ok(c) => c,
            Err(e) => {
                eprintln!("client: cannot connect to {tcp}: {e}");
                std::process::exit(1);
            }
        };
    }
    #[cfg(unix)]
    {
        let path = std::path::PathBuf::from(parsed.get("socket"));
        return match slope_screen::serve::client::connect_with_retry(&path, 20, 50) {
            Ok(c) => c,
            Err(e) => {
                eprintln!("client: cannot connect to {}: {e}", path.display());
                std::process::exit(1);
            }
        };
    }
    #[cfg(not(unix))]
    {
        eprintln!("client: unix sockets are unavailable on this platform; use --tcp HOST:PORT");
        std::process::exit(2);
    }
}

fn cmd_client(parsed: &slope_screen::cli::Parsed) {
    use std::io::BufRead as _;
    let mut client = dial_client(parsed);
    // Overload rejections and dropped connections back off and retry
    // (idempotent ops only); other typed errors are answers, printed as-is.
    let mut backoff = slope_screen::serve::client::Backoff::new(50, 5000, parsed.u64("seed"));
    let inline = parsed.get("json");
    if !inline.is_empty() {
        match client.round_trip_with_retry(inline, 5, &mut backoff) {
            Ok(resp) => println!("{resp}"),
            Err(e) => {
                eprintln!("client: {e}");
                std::process::exit(1);
            }
        }
        return;
    }
    // No --json: read request lines from stdin, print response lines.
    let stdin = std::io::stdin();
    for line in stdin.lock().lines() {
        let line = match line {
            Ok(l) => l,
            Err(e) => {
                eprintln!("client: stdin error: {e}");
                std::process::exit(1);
            }
        };
        if line.trim().is_empty() {
            continue;
        }
        match client.round_trip_with_retry(&line, 5, &mut backoff) {
            Ok(resp) => println!("{resp}"),
            Err(e) => {
                eprintln!("client: {e}");
                std::process::exit(1);
            }
        }
    }
}

/// Summarize a `--trace` JSONL file: per-span self-time (wall time minus
/// nested children, so the hot layer is the top row), point-event counts,
/// and the closing registry snapshot — with the paper's headline number,
/// gradient-sweep reduction, called out when the counters carry it.
fn cmd_profile(parsed: &slope_screen::cli::Parsed) {
    use slope_screen::benchkit::Table;
    let positional = parsed.positional();
    let Some(path) = positional.get(1) else {
        eprintln!("profile: usage: slope-screen profile <trace.jsonl>");
        std::process::exit(2);
    };
    let prof = slope_screen::obs::profile::profile_file(std::path::Path::new(path))
        .unwrap_or_else(|e| {
            eprintln!("profile: {e}");
            std::process::exit(1);
        });
    println!("{path}: {} records", prof.records);
    let mut spans = Table::new(
        "span self-time",
        &["span", "count", "total_s", "self_s", "mean_ms", "max_ms"],
    );
    for s in &prof.spans {
        spans.row(vec![
            s.name.clone(),
            s.count.to_string(),
            format!("{:.4}", s.total_us as f64 / 1e6),
            format!("{:.4}", s.self_us as f64 / 1e6),
            format!("{:.3}", s.total_us as f64 / 1e3 / s.count.max(1) as f64),
            format!("{:.3}", s.max_us as f64 / 1e3),
        ]);
    }
    spans.print();
    if !prof.events.is_empty() {
        let mut events = Table::new("events", &["event", "count"]);
        for (name, n) in &prof.events {
            events.row(vec![name.clone(), n.to_string()]);
        }
        events.print();
    }
    if !prof.counters.is_empty() {
        let mut counters = Table::new("counters", &["counter", "value"]);
        for (name, v) in &prof.counters {
            counters.row(vec![name.clone(), format!("{v}")]);
        }
        counters.print();
    }
    let get = |key: &str| prof.counters.iter().find(|(n, _)| n == key).map(|(_, v)| *v);
    if let (Some(full), Some(partial), Some(cols)) =
        (get("grad_full_sweeps"), get("grad_partial_sweeps"), get("grad_sweep_cols"))
    {
        println!(
            "\ngradient sweeps: {full:.0} full + {partial:.0} partial, {cols:.0} columns touched"
        );
    }
    if let (Some(degraded), Some(nonconverged)) =
        (get("path_degraded_steps"), get("fista_nonconverged"))
    {
        println!(
            "resilience: {degraded:.0} ladder-degraded path steps, {nonconverged:.0} uncertified FISTA solves"
        );
    }
}

fn cmd_info() {
    match Engine::cpu() {
        Ok(engine) => println!("PJRT platform: {}", engine.platform()),
        Err(e) => println!("PJRT unavailable: {e}"),
    }
    match Manifest::load(&slope_screen::runtime::default_artifact_dir()) {
        Ok(m) => {
            println!(
                "artifacts: {} entries (dtype {}, pad multiple {})",
                m.entries.len(),
                m.dtype,
                m.pad_multiple
            );
            for e in &m.entries {
                println!(
                    "  {:<8} {:<12} n={:<6} p={:<7} m={:<2} {}",
                    e.kind, e.family, e.n, e.p, e.m, e.file
                );
            }
        }
        Err(e) => println!("no artifact manifest: {e}"),
    }
}

//! Data generation: the synthetic designs of §3.2 and deterministic
//! simulated stand-ins for the paper's real datasets (§3.3), plus
//! export helpers ([`real::write_csv`] / [`real::write_svmlight`],
//! [`real::RealDataset::export`]) so the stand-ins double as round-trip
//! fixtures for the [`crate::ingest`] readers.
//!
//! See DESIGN.md §6 for the substitution rationale: the real datasets are
//! behind external hosts this environment cannot reach, so `real`
//! fabricates designs matching each dataset's dimensions, sparsity,
//! response family and correlation texture. The screening phenomena under
//! study depend on (n, p, correlation, signal sparsity) — all preserved.

pub mod real;
pub mod synth;

pub use real::RealDataset;
pub use synth::{chain_design, compound_design, iid_design, SyntheticSpec};

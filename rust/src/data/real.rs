//! Deterministic simulated stand-ins for the paper's real datasets
//! (§3.3, Tables 2–3, Figure 7).
//!
//! The originals live on external hosts unreachable from this
//! environment, so each dataset is replaced by a synthetic design with
//! the same `n`, `p`, response family, sparsity regime and a correlation
//! texture imitating the original's provenance (low-rank latent factors
//! for the microarray/mass-spec data, pixel-neighbour correlation for
//! zipcode, light correlation for the tabular sets). DESIGN.md §6 records
//! the substitution argument; the screening behaviour under study depends
//! on dimensions, correlation and signal sparsity — all preserved.

use std::io;
use std::path::{Path, PathBuf};

use crate::linalg::{Csc, Design, Mat, ParConfig};
use crate::rng::Pcg64;
use crate::slope::family::{sigmoid, Family, Problem};

/// Write a problem as dense CSV (`x1,…,xp,y` header, response last) with
/// shortest-round-trip float formatting — export → ingest is bitwise.
/// Delegates to [`crate::ingest::export::write_csv`].
pub fn write_csv(prob: &Problem, path: &Path) -> io::Result<()> {
    crate::ingest::export::write_csv(prob, path)
}

/// Write a problem as svmlight (`# … p=<p>` header, `label idx:val …`
/// rows, 1-based ascending indices). Delegates to
/// [`crate::ingest::export::write_svmlight`].
pub fn write_svmlight(prob: &Problem, path: &Path) -> io::Result<()> {
    crate::ingest::export::write_svmlight(prob, path)
}

/// Identifiers for the seven datasets used in §3.3.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RealDataset {
    /// Mass-spectrometry cancer detection, 100 × 9920, binary response.
    Arcene,
    /// Drug discovery, 800 × 88119, sparse binary features, binary response.
    Dorothea,
    /// Digit discrimination (4 vs 9), 6000 × 4955, binary response.
    Gisette,
    /// Leukemia microarray, 38 × 7129, binary response.
    Golub,
    /// Computer-activity tabular data, 8192 × 12, continuous response.
    Cpusmall,
    /// Physician-visit counts, 4406 × 25, count response.
    Physician,
    /// Handwritten digits, 200 × 256 (16×16 pixels), 10 classes.
    Zipcode,
}

impl RealDataset {
    /// All seven datasets.
    pub fn all() -> [RealDataset; 7] {
        [
            RealDataset::Arcene,
            RealDataset::Dorothea,
            RealDataset::Gisette,
            RealDataset::Golub,
            RealDataset::Cpusmall,
            RealDataset::Physician,
            RealDataset::Zipcode,
        ]
    }

    /// Display name.
    pub fn name(&self) -> &'static str {
        match self {
            RealDataset::Arcene => "arcene",
            RealDataset::Dorothea => "dorothea",
            RealDataset::Gisette => "gisette",
            RealDataset::Golub => "golub",
            RealDataset::Cpusmall => "cpusmall",
            RealDataset::Physician => "physician",
            RealDataset::Zipcode => "zipcode",
        }
    }

    /// (n, p) of the original.
    pub fn dims(&self) -> (usize, usize) {
        match self {
            RealDataset::Arcene => (100, 9920),
            RealDataset::Dorothea => (800, 88_119),
            RealDataset::Gisette => (6000, 4955),
            RealDataset::Golub => (38, 7129),
            RealDataset::Cpusmall => (8192, 12),
            RealDataset::Physician => (4406, 25),
            RealDataset::Zipcode => (200, 256),
        }
    }

    /// The family each dataset is modelled with in Table 3 (Table 2 uses
    /// OLS *and* logistic on the first four).
    pub fn table3_family(&self) -> Family {
        match self {
            RealDataset::Cpusmall => Family::Gaussian,
            RealDataset::Golub => Family::Binomial,
            RealDataset::Physician => Family::Poisson,
            RealDataset::Zipcode => Family::Multinomial { classes: 10 },
            // the remaining sets appear only in Table 2 / Fig 7
            _ => Family::Binomial,
        }
    }

    /// Generate the stand-in with the canonical seed (deterministic).
    pub fn load(&self) -> Problem {
        self.load_with(Family::Binomial, 0x5107e_u64 + ordinal(*self) as u64)
    }

    /// Export the stand-in (canonical seed) to `dir` in its natural
    /// format — sparse designs as `<name>.svm`, dense as `<name>.csv` —
    /// so the seven paper datasets double as ingest round-trip fixtures.
    /// Returns the written path.
    pub fn export(&self, dir: &Path) -> io::Result<PathBuf> {
        self.export_problem(&self.load(), dir)
    }

    /// [`RealDataset::export`] for an already-loaded problem (avoids
    /// regenerating a gisette-scale design just to write it out).
    pub fn export_problem(&self, prob: &Problem, dir: &Path) -> io::Result<PathBuf> {
        let path = match &prob.x {
            Design::Sparse(_) => dir.join(format!("{}.svm", self.name())),
            Design::Dense(_) => dir.join(format!("{}.csv", self.name())),
        };
        match &prob.x {
            Design::Sparse(_) => write_svmlight(prob, &path)?,
            Design::Dense(_) => write_csv(prob, &path)?,
        }
        Ok(path)
    }

    /// Generate with an explicit family (Table 2 fits OLS *and* logistic
    /// to binary responses — OLS on {0,1} targets, as the paper does).
    pub fn load_with(&self, family_for_binary: Family, seed: u64) -> Problem {
        let mut rng = Pcg64::new(seed);
        match self {
            RealDataset::Arcene => {
                latent_factor_binary(&mut rng, 100, 9920, 40, 30, 3.0, family_for_binary)
            }
            RealDataset::Dorothea => dorothea(&mut rng, family_for_binary),
            RealDataset::Gisette => {
                latent_factor_binary(&mut rng, 6000, 4955, 60, 50, 2.0, family_for_binary)
            }
            RealDataset::Golub => {
                latent_factor_binary(&mut rng, 38, 7129, 10, 20, 4.0, family_for_binary)
            }
            RealDataset::Cpusmall => cpusmall(&mut rng),
            RealDataset::Physician => physician(&mut rng),
            RealDataset::Zipcode => zipcode(&mut rng),
        }
    }
}

fn ordinal(d: RealDataset) -> usize {
    RealDataset::all().iter().position(|&x| x == d).unwrap()
}

/// Microarray/mass-spec texture: `X = Z W + noise` with `r` latent factors
/// (giving correlated gene blocks), binary labels from `k` informative
/// features. Used for arcene, gisette and golub.
fn latent_factor_binary(
    rng: &mut Pcg64,
    n: usize,
    p: usize,
    r: usize,
    k: usize,
    signal: f64,
    family: Family,
) -> Problem {
    // latent scores per observation
    let z: Vec<f64> = (0..n * r).map(|_| rng.normal()).collect();
    let mut x = Mat::zeros(n, p);
    // factor loadings are sparse: each feature loads on 1–3 factors
    for j in 0..p {
        let col = x.col_mut(j);
        let n_load = 1 + rng.below(3) as usize;
        let mut loadings = Vec::with_capacity(n_load);
        for _ in 0..n_load {
            loadings.push((rng.below(r as u64) as usize, rng.normal()));
        }
        for (i, c) in col.iter_mut().enumerate() {
            let mut v = 0.6 * rng.normal(); // idiosyncratic noise
            for &(f, w) in &loadings {
                v += w * z[i * r + f];
            }
            *c = v;
        }
    }
    // response from k informative features
    let mut eta = vec![0.0; n];
    for j in 0..k.min(p) {
        let w = signal * rng.sign() / (k as f64).sqrt();
        for (e, &v) in eta.iter_mut().zip(x.col(j)) {
            *e += w * v;
        }
    }
    let y: Vec<f64> = eta
        .iter()
        .map(|&e| if rng.bernoulli(sigmoid(e)) { 1.0 } else { 0.0 })
        .collect();
    x.standardize_with(true, true, ParConfig::default());
    finish_binary(x, y, family)
}

/// dorothea: sparse binary features (~0.9% density), binary response.
fn dorothea(rng: &mut Pcg64, family: Family) -> Problem {
    let (n, p) = RealDataset::Dorothea.dims();
    let density = 0.009;
    let k = 60; // informative features
    let mut cols: Vec<Vec<(usize, f64)>> = Vec::with_capacity(p);
    // latent binary "pharmacophore" groups drive correlated activations
    let r = 50;
    let groups: Vec<Vec<bool>> = (0..r)
        .map(|_| (0..n).map(|_| rng.bernoulli(0.08)).collect())
        .collect();
    for _ in 0..p {
        let mut col = Vec::new();
        let grp = &groups[rng.below(r as u64) as usize];
        for (i, &g) in grp.iter().enumerate() {
            let prob = if g { 0.35 } else { density * 0.6 };
            if rng.bernoulli(prob) {
                col.push((i, 1.0));
            }
        }
        cols.push(col);
    }
    let mut eta = vec![0.0f64; n];
    for (j, col) in cols.iter().enumerate().take(k) {
        let w = 1.6 * rng.sign();
        for &(i, v) in col {
            eta[i] += w * v;
        }
        let _ = j;
    }
    let y: Vec<f64> = eta
        .iter()
        .map(|&e| if rng.bernoulli(sigmoid(e - 0.4)) { 1.0 } else { 0.0 })
        .collect();
    let mut csc = Csc::from_columns(n, &cols);
    csc.scale_columns_with(ParConfig::default());
    match family {
        Family::Gaussian => {
            let mean = crate::linalg::ops::mean(&y);
            let yc: Vec<f64> = y.iter().map(|v| v - mean).collect();
            Problem::new(Design::Sparse(csc), yc, Family::Gaussian)
        }
        _ => Problem::new(Design::Sparse(csc), y, Family::Binomial),
    }
}

/// cpusmall: 12 correlated tabular system-activity features, continuous
/// response (here: a noisy nonlinear-ish combination).
fn cpusmall(rng: &mut Pcg64) -> Problem {
    let (n, p) = RealDataset::Cpusmall.dims();
    let mut x = crate::data::synth::chain_design(rng, n, p, 0.55);
    let beta: Vec<f64> = (0..p).map(|j| if j < 6 { rng.normal() * 1.5 } else { 0.0 }).collect();
    let mut eta = vec![0.0; n];
    x.gemv(&beta, &mut eta);
    let mut y: Vec<f64> =
        eta.iter().map(|&e| e + 0.5 * e.tanh() + rng.normal()).collect();
    x.standardize_with(true, true, ParConfig::default());
    let mean = crate::linalg::ops::mean(&y);
    for v in y.iter_mut() {
        *v -= mean;
    }
    Problem::new(Design::Dense(x), y, Family::Gaussian)
}

/// physician: 25 demographic/insurance covariates, office-visit counts.
fn physician(rng: &mut Pcg64) -> Problem {
    let (n, p) = RealDataset::Physician.dims();
    let mut x = Mat::zeros(n, p);
    for j in 0..p {
        // mix of binary indicators and continuous covariates
        let binary = j % 3 == 0;
        let col = x.col_mut(j);
        for c in col.iter_mut() {
            *c = if binary {
                if rng.bernoulli(0.4) {
                    1.0
                } else {
                    0.0
                }
            } else {
                rng.normal()
            };
        }
    }
    let beta: Vec<f64> = (0..p)
        .map(|j| if j < 8 { 0.12 * rng.sign() * (1.0 + rng.next_f64()) } else { 0.0 })
        .collect();
    let mut eta = vec![0.0; n];
    x.gemv(&beta, &mut eta);
    let y: Vec<f64> = eta
        .iter()
        .map(|&e| rng.poisson((0.8 + e).clamp(-30.0, 3.5).exp()) as f64)
        .collect();
    x.standardize_with(true, true, ParConfig::default());
    Problem::new(Design::Dense(x), y, Family::Poisson)
}

/// zipcode: 16×16 pixel digits, 10 classes; neighbouring pixels correlate
/// through smooth class templates.
fn zipcode(rng: &mut Pcg64) -> Problem {
    let (n, p) = RealDataset::Zipcode.dims();
    let classes = 10;
    let side = 16;
    // smooth random template per class: sum of a few Gaussian bumps
    let mut templates = vec![vec![0.0f64; p]; classes];
    for tpl in templates.iter_mut() {
        for _ in 0..4 {
            let cx = rng.uniform(2.0, 14.0);
            let cy = rng.uniform(2.0, 14.0);
            let amp = rng.uniform(1.0, 2.5);
            let s2 = rng.uniform(2.0, 8.0);
            for px in 0..side {
                for py in 0..side {
                    let d2 = (px as f64 - cx).powi(2) + (py as f64 - cy).powi(2);
                    tpl[py * side + px] += amp * (-d2 / (2.0 * s2)).exp();
                }
            }
        }
    }
    let mut x = Mat::zeros(n, p);
    let mut y = Vec::with_capacity(n);
    for i in 0..n {
        let cls = (i % classes) as usize;
        y.push(cls as f64);
        let tpl = &templates[cls];
        for j in 0..p {
            x.set(i, j, tpl[j] + 0.7 * rng.normal());
        }
    }
    x.standardize_with(true, true, ParConfig::default());
    Problem::new(Design::Dense(x), y, Family::Multinomial { classes })
}

fn finish_binary(x: Mat, y: Vec<f64>, family: Family) -> Problem {
    match family {
        Family::Gaussian => {
            // Table 2 fits OLS straight to the 0/1 labels (centered).
            let mean = crate::linalg::ops::mean(&y);
            let yc: Vec<f64> = y.iter().map(|v| v - mean).collect();
            Problem::new(Design::Dense(x), yc, Family::Gaussian)
        }
        _ => Problem::new(Design::Dense(x), y, Family::Binomial),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dims_match_paper() {
        assert_eq!(RealDataset::Arcene.dims(), (100, 9920));
        assert_eq!(RealDataset::Dorothea.dims(), (800, 88_119));
        assert_eq!(RealDataset::Gisette.dims(), (6000, 4955));
        assert_eq!(RealDataset::Golub.dims(), (38, 7129));
        assert_eq!(RealDataset::Cpusmall.dims(), (8192, 12));
        assert_eq!(RealDataset::Physician.dims(), (4406, 25));
        assert_eq!(RealDataset::Zipcode.dims(), (200, 256));
    }

    #[test]
    fn golub_standin_has_right_shape_and_labels() {
        let prob = RealDataset::Golub.load();
        assert_eq!(prob.n(), 38);
        assert_eq!(prob.p(), 7129);
        assert!(prob.y.iter().all(|&v| v == 0.0 || v == 1.0));
        assert!(prob.y.iter().any(|&v| v == 1.0));
        assert!(prob.y.iter().any(|&v| v == 0.0));
    }

    #[test]
    fn dorothea_standin_is_sparse() {
        let prob = RealDataset::Dorothea.load();
        match &prob.x {
            Design::Sparse(csc) => {
                let density = csc.nnz() as f64 / (csc.nrows() * csc.ncols()) as f64;
                assert!(density < 0.05, "density={density}");
                assert!(density > 0.001, "density={density}");
            }
            _ => panic!("dorothea must be sparse"),
        }
    }

    #[test]
    fn zipcode_standin_has_ten_classes() {
        let prob = RealDataset::Zipcode.load();
        assert_eq!(prob.family, Family::Multinomial { classes: 10 });
        let mut seen = [false; 10];
        for &v in &prob.y {
            seen[v as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn loads_are_deterministic() {
        let a = RealDataset::Golub.load();
        let b = RealDataset::Golub.load();
        assert_eq!(a.y, b.y);
    }

    #[test]
    fn ols_variant_centers_response() {
        let prob = RealDataset::Golub.load_with(Family::Gaussian, 123);
        assert_eq!(prob.family, Family::Gaussian);
        assert!(crate::linalg::ops::mean(&prob.y).abs() < 1e-9);
    }

    #[test]
    fn physician_counts() {
        let prob = RealDataset::Physician.load();
        assert_eq!(prob.family, Family::Poisson);
        assert!(prob.y.iter().all(|&v| v >= 0.0 && v.fract() == 0.0));
        // visits shouldn't be degenerate
        assert!(crate::linalg::ops::mean(&prob.y) > 0.2);
    }
}

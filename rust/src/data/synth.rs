//! Synthetic designs of §3.2.
//!
//! * [`compound_design`] — rows iid `N(0, Σ)` with compound symmetry
//!   `Σ_ij = ρ + (1−ρ)·1{i=j}` (§3.2.1), generated via the one-factor
//!   identity `x = √ρ·z·1 + √(1−ρ)·ε` (no p×p Cholesky needed).
//! * [`chain_design`] — the §3.2.3 construction `X_1 ~ N(0, I)`,
//!   `X_j ~ N(ρ X_{j−1}, I)`.
//! * [`iid_design`] — independent standard normal columns (Fig. 5).
//! * Coefficient and response generators for the four families, matching
//!   the parameter choices quoted in the paper for each experiment.

use crate::linalg::{Design, Mat, ParConfig};
use crate::rng::Pcg64;
use crate::slope::family::{Family, Problem};

/// Compound-symmetric design: every pair of predictors has correlation ρ.
pub fn compound_design(rng: &mut Pcg64, n: usize, p: usize, rho: f64) -> Mat {
    assert!((0.0..1.0).contains(&rho), "rho must be in [0,1)");
    let sr = rho.sqrt();
    let sc = (1.0 - rho).sqrt();
    let mut x = Mat::zeros(n, p);
    // factor draws per row
    let z: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    for j in 0..p {
        let col = x.col_mut(j);
        for (i, c) in col.iter_mut().enumerate() {
            *c = sr * z[i] + sc * rng.normal();
        }
    }
    x
}

/// Markov-chain design of §3.2.3: `X_j ~ N(ρ X_{j−1}, I)` column-wise.
pub fn chain_design(rng: &mut Pcg64, n: usize, p: usize, rho: f64) -> Mat {
    let mut x = Mat::zeros(n, p);
    for j in 0..p {
        // borrow discipline: copy the previous column first
        let prev: Option<Vec<f64>> = if j > 0 { Some(x.col(j - 1).to_vec()) } else { None };
        let col = x.col_mut(j);
        match prev {
            None => {
                for c in col.iter_mut() {
                    *c = rng.normal();
                }
            }
            Some(prev) => {
                for (c, &pv) in col.iter_mut().zip(&prev) {
                    *c = rho * pv + rng.normal();
                }
            }
        }
    }
    x
}

/// Independent standard-normal columns (Fig. 5's "orthonormal-ish" case).
pub fn iid_design(rng: &mut Pcg64, n: usize, p: usize) -> Mat {
    let mut x = Mat::zeros(n, p);
    for j in 0..p {
        for c in x.col_mut(j).iter_mut() {
            *c = rng.normal();
        }
    }
    x
}

/// How the true β is drawn (the paper varies this across experiments).
#[derive(Clone, Copy, Debug)]
pub enum BetaSpec {
    /// First k entries iid `N(0, 1)` (§3.2.1).
    Normal {
        /// Number of nonzero coefficients.
        k: usize,
    },
    /// First k entries sampled from `{−scale, +scale}` (§3.2.1 Fig 2, §3.2.2).
    PlusMinus {
        /// Number of nonzero coefficients.
        k: usize,
        /// Magnitude.
        scale: f64,
    },
    /// First k entries sampled *without replacement* from
    /// `{step, 2·step, …, k·step}` (§3.2.3: step=1 for OLS/logistic,
    /// step=1/40 for Poisson).
    Ladder {
        /// Number of nonzero coefficients.
        k: usize,
        /// Spacing of the ladder.
        step: f64,
    },
}

impl BetaSpec {
    /// Draw the coefficient vector of length p.
    pub fn draw(&self, rng: &mut Pcg64, p: usize) -> Vec<f64> {
        let mut beta = vec![0.0; p];
        match *self {
            BetaSpec::Normal { k } => {
                for b in beta.iter_mut().take(k.min(p)) {
                    *b = rng.normal();
                }
            }
            BetaSpec::PlusMinus { k, scale } => {
                for b in beta.iter_mut().take(k.min(p)) {
                    *b = scale * rng.sign();
                }
            }
            BetaSpec::Ladder { k, step } => {
                let k = k.min(p);
                let ladder: Vec<f64> = (1..=k).map(|i| i as f64 * step).collect();
                let values = rng.sample_without_replacement(&ladder, k);
                for (b, v) in beta.iter_mut().zip(values) {
                    *b = v;
                }
            }
        }
        beta
    }
}

/// Full synthetic-problem specification.
#[derive(Clone, Debug)]
pub struct SyntheticSpec {
    /// Observations.
    pub n: usize,
    /// Predictors.
    pub p: usize,
    /// Correlation parameter (meaning depends on `design`).
    pub rho: f64,
    /// `"compound" | "chain" | "iid"`.
    pub design: DesignKind,
    /// Coefficient spec.
    pub beta: BetaSpec,
    /// Response family.
    pub family: Family,
    /// Noise standard deviation for OLS / the latent logistic score
    /// (§3.2.3 uses ε ~ N(0, 20·I) ⇒ sd = √20).
    pub noise_sd: f64,
    /// Standardize columns (center + unit norm) and center y for OLS, as
    /// in §3.1.
    pub standardize: bool,
}

/// Design-matrix construction.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum DesignKind {
    /// Compound symmetry (§3.2.1).
    Compound,
    /// Markov chain (§3.2.3).
    Chain,
    /// iid columns (Fig. 5).
    Iid,
}

impl SyntheticSpec {
    /// Generate a problem instance.
    pub fn generate(&self, rng: &mut Pcg64) -> Problem {
        let mut x = match self.design {
            DesignKind::Compound => compound_design(rng, self.n, self.p, self.rho),
            DesignKind::Chain => chain_design(rng, self.n, self.p, self.rho),
            DesignKind::Iid => iid_design(rng, self.n, self.p),
        };
        let beta = self.beta.draw(rng, self.p * self.family.n_classes());
        // responses are generated on the *unstandardized* design (as in the
        // paper), standardization happens afterwards
        let y = draw_response(rng, &x, &beta, self.family, self.noise_sd);
        if self.standardize {
            x.standardize_with(true, true, ParConfig::default());
        }
        let mut y = y;
        if self.standardize && self.family == Family::Gaussian {
            let mean = crate::linalg::ops::mean(&y);
            for v in y.iter_mut() {
                *v -= mean;
            }
        }
        Problem::new(Design::Dense(x), y, self.family)
    }
}

/// Draw a response vector for the given design/coefficients/family.
pub fn draw_response(
    rng: &mut Pcg64,
    x: &Mat,
    beta: &[f64],
    family: Family,
    noise_sd: f64,
) -> Vec<f64> {
    let n = x.nrows();
    let p = x.ncols();
    let m = family.n_classes();
    assert_eq!(beta.len(), p * m);
    let mut eta = vec![0.0; n * m];
    for l in 0..m {
        let mut out = vec![0.0; n];
        x.gemv(&beta[l * p..(l + 1) * p], &mut out);
        eta[l * n..(l + 1) * n].copy_from_slice(&out);
    }
    match family {
        Family::Gaussian => (0..n).map(|i| eta[i] + noise_sd * rng.normal()).collect(),
        // §3.2.3: y = sign(Xβ + ε) mapped to {0, 1}.
        Family::Binomial => (0..n)
            .map(|i| if eta[i] + noise_sd * rng.normal() > 0.0 { 1.0 } else { 0.0 })
            .collect(),
        Family::Poisson => (0..n)
            .map(|i| rng.poisson(eta[i].clamp(-30.0, 30.0).exp()) as f64)
            .collect(),
        Family::Multinomial { classes } => (0..n)
            .map(|i| {
                // softmax draw
                let mut maxe = f64::NEG_INFINITY;
                for l in 0..classes {
                    maxe = maxe.max(eta[l * n + i]);
                }
                let weights: Vec<f64> =
                    (0..classes).map(|l| (eta[l * n + i] - maxe).exp()).collect();
                let total: f64 = weights.iter().sum();
                let mut u = rng.next_f64() * total;
                let mut cls = classes - 1;
                for (l, w) in weights.iter().enumerate() {
                    if u < *w {
                        cls = l;
                        break;
                    }
                    u -= w;
                }
                cls as f64
            })
            .collect(),
    }
}

/// §3.2.3 multinomial β: for each of the first k rows, one uniformly-chosen
/// class gets a value sampled without replacement from `{1, …, k}`.
pub fn multinomial_beta(rng: &mut Pcg64, p: usize, k: usize, classes: usize) -> Vec<f64> {
    let mut beta = vec![0.0; p * classes];
    let ladder: Vec<f64> = (1..=k).map(|i| i as f64).collect();
    let values = rng.sample_without_replacement(&ladder, k);
    for (row, v) in values.into_iter().enumerate() {
        let class = rng.below(classes as u64) as usize;
        beta[class * p + row] = v;
    }
    beta
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::dense::dot;

    fn col_corr(x: &Mat, a: usize, b: usize) -> f64 {
        let n = x.nrows() as f64;
        let ca = x.col(a);
        let cb = x.col(b);
        let ma = ca.iter().sum::<f64>() / n;
        let mb = cb.iter().sum::<f64>() / n;
        let mut num = 0.0;
        let mut va = 0.0;
        let mut vb = 0.0;
        for i in 0..x.nrows() {
            num += (ca[i] - ma) * (cb[i] - mb);
            va += (ca[i] - ma) * (ca[i] - ma);
            vb += (cb[i] - mb) * (cb[i] - mb);
        }
        num / (va.sqrt() * vb.sqrt())
    }

    #[test]
    fn compound_design_hits_target_correlation() {
        let mut rng = Pcg64::new(1);
        let x = compound_design(&mut rng, 4000, 6, 0.6);
        let mut sum = 0.0;
        let mut count = 0;
        for a in 0..6 {
            for b in (a + 1)..6 {
                sum += col_corr(&x, a, b);
                count += 1;
            }
        }
        let mean_corr = sum / count as f64;
        assert!((mean_corr - 0.6).abs() < 0.05, "corr={mean_corr}");
    }

    #[test]
    fn chain_design_decaying_correlation() {
        let mut rng = Pcg64::new(2);
        let x = chain_design(&mut rng, 5000, 5, 0.9);
        let c01 = col_corr(&x, 0, 1);
        let c04 = col_corr(&x, 0, 4);
        assert!(c01 > 0.5, "adjacent corr too low: {c01}");
        assert!(c04 < c01, "correlation should decay along the chain");
    }

    #[test]
    fn iid_design_uncorrelated() {
        let mut rng = Pcg64::new(3);
        let x = iid_design(&mut rng, 5000, 4);
        for a in 0..4 {
            for b in (a + 1)..4 {
                assert!(col_corr(&x, a, b).abs() < 0.05);
            }
        }
    }

    #[test]
    fn beta_specs_have_right_support() {
        let mut rng = Pcg64::new(4);
        let b1 = BetaSpec::Normal { k: 5 }.draw(&mut rng, 20);
        assert_eq!(b1.iter().filter(|&&v| v != 0.0).count(), 5);
        let b2 = BetaSpec::PlusMinus { k: 3, scale: 2.0 }.draw(&mut rng, 10);
        assert!(b2[..3].iter().all(|&v| v.abs() == 2.0));
        assert!(b2[3..].iter().all(|&v| v == 0.0));
        let b3 = BetaSpec::Ladder { k: 4, step: 0.5 }.draw(&mut rng, 10);
        let mut nz: Vec<f64> = b3.iter().copied().filter(|&v| v != 0.0).collect();
        nz.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(nz, vec![0.5, 1.0, 1.5, 2.0]);
    }

    #[test]
    fn generate_standardizes() {
        let spec = SyntheticSpec {
            n: 50,
            p: 10,
            rho: 0.3,
            design: DesignKind::Compound,
            beta: BetaSpec::PlusMinus { k: 2, scale: 2.0 },
            family: Family::Gaussian,
            noise_sd: 1.0,
            standardize: true,
        };
        let mut rng = Pcg64::new(5);
        let prob = spec.generate(&mut rng);
        let x = prob.x.as_dense().unwrap();
        for j in 0..x.ncols() {
            let col = x.col(j);
            let norm = dot(col, col).sqrt();
            assert!((norm - 1.0).abs() < 1e-9);
        }
        let ymean = crate::linalg::ops::mean(&prob.y);
        assert!(ymean.abs() < 1e-9);
    }

    #[test]
    fn binomial_response_is_binary() {
        let spec = SyntheticSpec {
            n: 100,
            p: 5,
            rho: 0.0,
            design: DesignKind::Iid,
            beta: BetaSpec::PlusMinus { k: 2, scale: 1.0 },
            family: Family::Binomial,
            noise_sd: (20.0f64).sqrt(),
            standardize: true,
        };
        let mut rng = Pcg64::new(6);
        let prob = spec.generate(&mut rng);
        assert!(prob.y.iter().all(|&v| v == 0.0 || v == 1.0));
        // both classes should appear
        assert!(prob.y.iter().any(|&v| v == 0.0) && prob.y.iter().any(|&v| v == 1.0));
    }

    #[test]
    fn multinomial_beta_layout() {
        let mut rng = Pcg64::new(7);
        let beta = multinomial_beta(&mut rng, 10, 4, 3);
        assert_eq!(beta.len(), 30);
        // exactly 4 nonzeros, all in the first 4 predictor rows
        let nz: Vec<usize> = beta
            .iter()
            .enumerate()
            .filter(|(_, &v)| v != 0.0)
            .map(|(i, _)| i % 10)
            .collect();
        assert_eq!(nz.len(), 4);
        assert!(nz.iter().all(|&r| r < 4));
    }

    #[test]
    fn poisson_response_nonnegative_integers() {
        let spec = SyntheticSpec {
            n: 60,
            p: 8,
            rho: 0.5,
            design: DesignKind::Chain,
            beta: BetaSpec::Ladder { k: 4, step: 1.0 / 40.0 },
            family: Family::Poisson,
            noise_sd: 0.0,
            standardize: true,
        };
        let mut rng = Pcg64::new(8);
        let prob = spec.generate(&mut rng);
        assert!(prob.y.iter().all(|&v| v >= 0.0 && v.fract() == 0.0));
    }

    #[test]
    fn deterministic_given_seed() {
        let spec = SyntheticSpec {
            n: 20,
            p: 6,
            rho: 0.2,
            design: DesignKind::Compound,
            beta: BetaSpec::Normal { k: 2 },
            family: Family::Gaussian,
            noise_sd: 1.0,
            standardize: false,
        };
        let p1 = spec.generate(&mut Pcg64::new(42));
        let p2 = spec.generate(&mut Pcg64::new(42));
        assert_eq!(p1.y, p2.y);
        assert_eq!(p1.x.as_dense().unwrap().data(), p2.x.as_dense().unwrap().data());
    }
}

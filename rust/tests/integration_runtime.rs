//! Integration: the PJRT runtime against the native reference — artifact
//! gradients must agree with native gradients to near machine precision,
//! for every family with a core artifact, including repeat execution
//! (device-buffer reuse) and the screening scan.
//!
//! Requires `make artifacts` (skips gracefully when absent so `cargo test`
//! works in a fresh checkout).

use slope_screen::data::synth::{BetaSpec, DesignKind, SyntheticSpec};
use slope_screen::rng::Pcg64;
use slope_screen::runtime::{default_artifact_dir, ArtifactGradient, Manifest};
use slope_screen::slope::family::Family;
use slope_screen::slope::path::FullGradient;

fn manifest_or_skip() -> Option<Manifest> {
    match Manifest::load(&default_artifact_dir()) {
        Ok(m) => Some(m),
        Err(e) => {
            eprintln!("skipping runtime integration tests: {e}");
            None
        }
    }
}

fn problem(family: Family, n: usize, p: usize, seed: u64) -> slope_screen::slope::family::Problem {
    SyntheticSpec {
        n,
        p,
        rho: 0.3,
        design: DesignKind::Compound,
        beta: match family {
            Family::Poisson => BetaSpec::Ladder { k: 5, step: 1.0 / 40.0 },
            _ => BetaSpec::PlusMinus { k: 5, scale: 1.5 },
        },
        family,
        noise_sd: 1.0,
        standardize: true,
    }
    .generate(&mut Pcg64::new(seed))
}

fn check_family(manifest: &Manifest, family: Family, seed: u64) {
    let prob = problem(family, 90, 300, seed);
    let grad_xla = ArtifactGradient::new(manifest, &prob).expect("artifact");
    let pt = prob.p_total();
    let mut rng = Pcg64::new(seed ^ 0xfeed);
    for trial in 0..3 {
        // random (sparse-ish) beta
        let beta: Vec<f64> = (0..pt)
            .map(|_| if rng.bernoulli(0.2) { rng.normal() } else { 0.0 })
            .collect();
        let (_, want) = prob.loss_grad(&beta);
        // h as the native path would compute it
        let n = prob.n();
        let m = prob.family.n_classes();
        let mut eta = vec![0.0; n * m];
        prob.eta(&beta, &mut eta);
        let mut h = vec![0.0; n * m];
        prob.family.h_loss(&eta, &prob.y, &mut h);
        let mut got = vec![0.0; pt];
        grad_xla.full_grad(&beta, &h, &mut got);
        for i in 0..pt {
            assert!(
                (got[i] - want[i]).abs() < 1e-9 * (1.0 + want[i].abs()),
                "{} trial {trial} coef {i}: xla {} vs native {}",
                family.name(),
                got[i],
                want[i]
            );
        }
    }
}

#[test]
fn artifact_gradient_matches_native_gaussian() {
    if let Some(m) = manifest_or_skip() {
        check_family(&m, Family::Gaussian, 21);
    }
}

#[test]
fn artifact_gradient_matches_native_binomial() {
    if let Some(m) = manifest_or_skip() {
        check_family(&m, Family::Binomial, 22);
    }
}

#[test]
fn artifact_gradient_matches_native_poisson() {
    if let Some(m) = manifest_or_skip() {
        check_family(&m, Family::Poisson, 23);
    }
}

#[test]
fn artifact_gradient_matches_native_multinomial() {
    if let Some(m) = manifest_or_skip() {
        check_family(&m, Family::Multinomial { classes: 3 }, 24);
    }
}

/// The whole path machinery over the XLA engine agrees with native.
#[test]
fn full_path_agrees_across_engines() {
    use slope_screen::slope::lambda::{LambdaKind, PathConfig};
    use slope_screen::slope::path::{fit_path, NativeGradient, PathOptions};
    let Some(manifest) = manifest_or_skip() else { return };
    let prob = problem(Family::Binomial, 80, 256, 31);
    let mut cfg = PathConfig::new(LambdaKind::Bh { q: 0.1 });
    cfg.length = 15;
    let opts = PathOptions::new(cfg);
    let native = fit_path(&prob, &opts, &NativeGradient(&prob));
    let grad = ArtifactGradient::new(&manifest, &prob).expect("artifact");
    let xla = fit_path(&prob, &opts, &grad);
    assert_eq!(native.steps.len(), xla.steps.len());
    for m in 0..native.steps.len() {
        let a = native.beta_at(m, prob.p_total());
        let b = xla.beta_at(m, prob.p_total());
        for i in 0..prob.p_total() {
            assert!((a[i] - b[i]).abs() < 1e-5, "step {m} coef {i}");
        }
    }
}

/// Screening scan artifact = Algorithm 1's criterion, against native cumsum.
#[test]
fn screen_artifact_matches_native() {
    use slope_screen::linalg::ops::cumsum;
    use slope_screen::runtime::gradient::ScreenExecutor;
    let Some(manifest) = manifest_or_skip() else { return };
    let p = 300;
    let mut rng = Pcg64::new(41);
    let mut c: Vec<f64> = (0..p).map(|_| rng.normal().abs()).collect();
    c.sort_by(|a, b| b.partial_cmp(a).unwrap());
    let lam: Vec<f64> = (0..p).map(|i| 2.0 - 1.5 * i as f64 / p as f64).collect();
    let screen = ScreenExecutor::new(&manifest, p).expect("screen artifact");
    let got = screen.cumsum(&c, &lam).expect("cumsum");
    let diffs: Vec<f64> = c.iter().zip(&lam).map(|(a, b)| a - b).collect();
    let want = cumsum(&diffs);
    for i in 0..p {
        assert!((got[i] - want[i]).abs() < 1e-9, "index {i}: {} vs {}", got[i], want[i]);
    }
}

/// Bucket fallback: a problem smaller than any bucket gets padded up; a
/// problem larger than all buckets errors with guidance.
#[test]
fn bucket_selection_behaviour() {
    let Some(manifest) = manifest_or_skip() else { return };
    let small = problem(Family::Gaussian, 10, 17, 51);
    let g = ArtifactGradient::new(&manifest, &small).expect("small bucket");
    assert!(g.padding_overhead() >= 1.0);
    let huge = problem(Family::Gaussian, 64, 30_000, 52);
    let err = ArtifactGradient::new(&manifest, &huge);
    assert!(err.is_err());
    let msg = format!("{:#}", err.err().unwrap());
    assert!(msg.contains("aot"), "unhelpful error: {msg}");
}

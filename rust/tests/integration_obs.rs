//! Integration: the observability layer is invisible to the solver.
//!
//! The contract the tracing/counter subsystem sells is "leave the
//! instrumentation in the hot path permanently": counters are relaxed
//! atomics and spans branch on one load, so enabling a trace must change
//! *nothing* about the arithmetic. This test proves it differentially —
//! the same fit with tracing off and tracing on must be **bitwise**
//! identical, across kernel thread counts — and then closes the loop on
//! the trace artifact itself: the JSONL a real fit writes parses line by
//! line, carries one span per σ-step, and aggregates through
//! [`slope_screen::obs::profile`] into a non-empty self-time table.

use slope_screen::data::synth::{BetaSpec, DesignKind, SyntheticSpec};
use slope_screen::jsonio::Json;
use slope_screen::obs::{profile, trace};
use slope_screen::rng::Pcg64;
use slope_screen::slope::family::{Family, Problem};
use slope_screen::slope::lambda::{LambdaKind, PathConfig};
use slope_screen::slope::path::{fit_path, NativeGradient, PathFit, PathOptions, Strategy};

fn problem() -> Problem {
    SyntheticSpec {
        n: 40,
        p: 120,
        rho: 0.2,
        design: DesignKind::Compound,
        beta: BetaSpec::PlusMinus { k: 8, scale: 2.0 },
        family: Family::Gaussian,
        noise_sd: 1.0,
        standardize: true,
    }
    .generate(&mut Pcg64::new(2020))
}

fn fit(prob: &Problem, threads: usize) -> PathFit {
    let mut cfg = PathConfig::new(LambdaKind::Bh { q: 0.1 });
    cfg.length = 12;
    let o = PathOptions::new(cfg)
        .with_strategy(Strategy::StrongSet)
        .with_threads(threads);
    fit_path(prob, &o, &NativeGradient(prob))
}

#[test]
fn tracing_is_bitwise_invisible_and_the_trace_profiles() {
    // The tracer is process-global: serialize against any other test
    // that toggles it (unit tests in the library share the guard).
    let _g = trace::test_guard();
    let prob = problem();
    let trace_path = std::env::temp_dir().join(format!(
        "slope_obs_itest_{}.jsonl",
        std::process::id()
    ));

    for &threads in &[1usize, 2, 7] {
        assert!(trace::disabled(), "tracing must start disabled");
        let plain = fit(&prob, threads);

        trace::enable_file(&trace_path).expect("enable trace sink");
        let traced = fit(&prob, threads);
        trace::disable();
        assert!(trace::disabled(), "disable() must turn tracing off");

        // The differential core: not "close", *bitwise*. Any branch the
        // instrumentation adds to the numeric path would show up here.
        assert_eq!(plain.steps.len(), traced.steps.len(), "threads={threads}");
        assert_eq!(
            plain.total_violations, traced.total_violations,
            "threads={threads}"
        );
        assert_eq!(plain.final_beta.len(), traced.final_beta.len());
        for (i, (a, b)) in plain.final_beta.iter().zip(&traced.final_beta).enumerate() {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "threads={threads}: coefficient {i} differs bitwise ({a} vs {b})"
            );
        }
        for (x, y) in plain.final_grad.iter().zip(&traced.final_grad) {
            assert_eq!(x.to_bits(), y.to_bits(), "threads={threads}: gradient differs");
        }

        // The artifact: well-formed JSONL, a meta header, the closing
        // counters record, and per-step spans under the path_fit span.
        let text = std::fs::read_to_string(&trace_path).expect("trace file");
        let mut path_steps = 0usize;
        let mut path_fits = 0usize;
        let mut saw_meta = false;
        let mut saw_counters = false;
        for line in text.lines().filter(|l| !l.trim().is_empty()) {
            let j = Json::parse(line).expect("every trace line parses as JSON");
            match j.field("ev").and_then(|e| e.as_str()) {
                Some("meta") => saw_meta = true,
                Some("counters") => saw_counters = true,
                Some("span") => match j.field("name").and_then(|n| n.as_str()) {
                    Some("path_step") => path_steps += 1,
                    Some("path_fit") => path_fits += 1,
                    _ => {}
                },
                _ => {}
            }
        }
        assert!(saw_meta, "threads={threads}: missing meta header");
        assert!(saw_counters, "threads={threads}: missing closing counters record");
        assert_eq!(path_fits, 1, "threads={threads}: exactly one fit-level span");
        // The β = 0 anchor step is recorded without a solve (no span);
        // every solved step gets one.
        assert!(
            path_steps >= traced.steps.len().saturating_sub(1) && path_steps >= 1,
            "threads={threads}: {path_steps} path_step spans for {} steps",
            traced.steps.len()
        );

        // And the profile aggregator reads the same file back.
        let prof = profile::profile_file(&trace_path).expect("profile the trace");
        assert!(prof.records > 0);
        assert!(
            prof.spans.iter().any(|s| s.name == "path_step"),
            "threads={threads}: profile lost the path_step spans"
        );
        assert!(
            !prof.counters.is_empty(),
            "threads={threads}: profile lost the counters record"
        );
        let step = prof.spans.iter().find(|s| s.name == "path_step").unwrap();
        assert_eq!(step.count as usize, path_steps);
        assert!(step.total_us >= step.self_us, "self-time cannot exceed total");
    }
    let _ = std::fs::remove_file(&trace_path);
}

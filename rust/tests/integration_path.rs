//! Integration: full path fits across families, strategies and penalty
//! shapes — solution agreement, screening safety, early stopping.

use slope_screen::data::synth::{BetaSpec, DesignKind, SyntheticSpec};
use slope_screen::rng::Pcg64;
use slope_screen::slope::family::Family;
use slope_screen::slope::lambda::{LambdaKind, PathConfig};
use slope_screen::slope::path::{fit_path, NativeGradient, PathOptions, Strategy};
use slope_screen::slope::sorted::support;

fn spec(n: usize, p: usize, k: usize, rho: f64, family: Family) -> SyntheticSpec {
    SyntheticSpec {
        n,
        p,
        rho,
        design: DesignKind::Compound,
        beta: match family {
            Family::Poisson => BetaSpec::Ladder { k, step: 1.0 / 40.0 },
            _ => BetaSpec::PlusMinus { k, scale: 2.0 },
        },
        family,
        noise_sd: 1.0,
        standardize: true,
    }
}

fn opts(kind: LambdaKind, strategy: Strategy, len: usize) -> PathOptions {
    let mut cfg = PathConfig::new(kind);
    cfg.length = len;
    PathOptions::new(cfg).with_strategy(strategy)
}

/// The three strategies are *exact* reformulations of one another: every
/// family must produce identical paths (up to solver tolerance).
#[test]
fn strategies_agree_across_families() {
    let families = [
        Family::Gaussian,
        Family::Binomial,
        Family::Poisson,
        Family::Multinomial { classes: 3 },
    ];
    for (fi, family) in families.into_iter().enumerate() {
        let prob = spec(50, 60, 5, 0.3, family).generate(&mut Pcg64::new(100 + fi as u64));
        let fit_of = |s| {
            let mut o = opts(LambdaKind::Bh { q: 0.1 }, s, 12);
            // Tight solves so strategy comparisons measure screening, not
            // solver noise.
            o.kkt_tol = 1e-7;
            fit_path(&prob, &o, &NativeGradient(&prob))
        };
        let a = fit_of(Strategy::NoScreening);
        let b = fit_of(Strategy::StrongSet);
        let c = fit_of(Strategy::PreviousSet);
        let steps = a.steps.len().min(b.steps.len()).min(c.steps.len());
        assert!(steps > 3, "{}: path too short", family.name());
        for m in 0..steps {
            let (x, y, z) = (
                a.beta_at(m, prob.p_total()),
                b.beta_at(m, prob.p_total()),
                c.beta_at(m, prob.p_total()),
            );
            for i in 0..prob.p_total() {
                assert!(
                    (x[i] - y[i]).abs() < 5e-4,
                    "{} strong vs none at step {m}, coef {i}: {} vs {}",
                    family.name(),
                    y[i],
                    x[i]
                );
                assert!(
                    (x[i] - z[i]).abs() < 5e-4,
                    "{} previous vs none at step {m}, coef {i}: {} vs {}",
                    family.name(),
                    z[i],
                    x[i]
                );
            }
        }
    }
}

/// Lasso-sequence SLOPE must match a hand-rolled coordinate-free lasso
/// check: with constant λ the screened set equals the classical strong
/// rule set (Proposition 3) along a real path.
#[test]
fn lasso_reduction_along_path() {
    let prob = spec(40, 80, 5, 0.0, Family::Gaussian).generate(&mut Pcg64::new(7));
    let o = opts(LambdaKind::Lasso, Strategy::StrongSet, 10);
    let fit = fit_path(&prob, &o, &NativeGradient(&prob));
    // recompute the screened sets from the recorded solutions
    for m in 1..fit.steps.len() {
        let beta_prev = fit.beta_at(m - 1, prob.p_total());
        let (_, grad) = prob.loss_grad(&beta_prev);
        let lam_prev = fit.sigmas[m - 1];
        let lam_cur = fit.sigmas[m];
        let lasso_set = slope_screen::slope::screen::lasso_strong_set(&grad, lam_prev, lam_cur);
        let slope_set = slope_screen::slope::screen::strong_set(
            &grad,
            &vec![lam_prev; prob.p_total()],
            &vec![lam_cur; prob.p_total()],
        );
        assert_eq!(lasso_set, slope_set, "step {m}");
    }
}

/// Screening must be *safe after the safeguard*: final fitted set ⊇
/// active set, and the recorded active sizes match the solutions.
#[test]
fn safeguard_invariants() {
    let prob = spec(60, 150, 8, 0.5, Family::Gaussian).generate(&mut Pcg64::new(8));
    let o = opts(LambdaKind::Bh { q: 0.05 }, Strategy::PreviousSet, 20);
    let fit = fit_path(&prob, &o, &NativeGradient(&prob));
    for (m, step) in fit.steps.iter().enumerate() {
        let beta = fit.beta_at(m, prob.p_total());
        assert_eq!(support(&beta).len(), step.n_active, "step {m} active mismatch");
        assert!(step.n_fitted >= step.n_active, "step {m}: E smaller than active");
    }
}

/// OSCAR and Gaussian sequences drive the path without violations on
/// benign data.
#[test]
fn alternative_sequences_run_clean() {
    let prob = spec(50, 100, 5, 0.2, Family::Gaussian).generate(&mut Pcg64::new(9));
    for kind in [
        LambdaKind::Oscar { q: 0.01 },
        LambdaKind::Gaussian { q: 0.05, n: 50 },
    ] {
        let o = opts(kind, Strategy::StrongSet, 15);
        let fit = fit_path(&prob, &o, &NativeGradient(&prob));
        assert!(fit.steps.last().unwrap().n_active > 0, "{:?} found nothing", kind);
    }
}

/// Early-stop rule 1 (unique magnitudes > n) fires on heavily saturated
/// fits: tiny n, long path, no other stops.
/// Early-stop rule 1 (unique magnitudes > n). With tightly converged
/// solutions SLOPE's clustering keeps unique magnitudes ≤ n (the pattern
/// results of Schneider & Tardivel), so the rule is a guard against
/// *loosely solved* saturated fits — exercise it with a deliberately
/// loose solver.
#[test]
fn saturation_stop_fires_for_loose_solves() {
    let prob = spec(10, 150, 10, 0.0, Family::Gaussian).generate(&mut Pcg64::new(10));
    let mut cfg = PathConfig::new(LambdaKind::Bh { q: 0.2 });
    cfg.length = 80;
    cfg.sigma_min_ratio = Some(1e-5);
    cfg.stop_on_dev_change = false;
    cfg.stop_on_dev_ratio = false;
    let mut o = PathOptions::new(cfg);
    o.fista.tol = 1e-3; // loose: near-ties stay distinct floats
    o.fista.max_iter = 300;
    o.fista.kkt_tol_abs = Some(f64::INFINITY); // disable KKT-verified mode
    o.kkt_tol = 1e6; // and the violation safeguard (it would refit forever)
    o.degrade = false; // the ladder would mask the loose solves under study
    let fit = fit_path(&prob, &o, &NativeGradient(&prob));
    assert_eq!(fit.stopped_early, Some("unique magnitudes exceed n"));
}

/// With tight solves on the same configuration, the clustering property
/// holds along the whole path: unique nonzero magnitudes never exceed n,
/// and the path runs to completion.
#[test]
fn tight_solves_respect_pattern_bound() {
    use slope_screen::slope::sorted::unique_nonzero_magnitudes;
    let prob = spec(10, 150, 10, 0.0, Family::Gaussian).generate(&mut Pcg64::new(10));
    let mut cfg = PathConfig::new(LambdaKind::Bh { q: 0.2 });
    cfg.length = 40;
    cfg.stop_on_dev_change = false;
    cfg.stop_on_dev_ratio = false;
    let o = PathOptions::new(cfg);
    let fit = fit_path(&prob, &o, &NativeGradient(&prob));
    for m in 0..fit.steps.len() {
        let beta = fit.beta_at(m, prob.p_total());
        assert!(
            unique_nonzero_magnitudes(&beta) <= prob.n(),
            "step {m}: clustering bound violated"
        );
    }
}

/// Sparse designs (CSC) run the whole path machinery.
#[test]
fn sparse_design_path() {
    use slope_screen::linalg::{Csc, Design, Mat};
    let mut rng = Pcg64::new(11);
    let (n, p) = (60, 200);
    let mut dense = Mat::zeros(n, p);
    for j in 0..p {
        for i in 0..n {
            if rng.bernoulli(0.05) {
                dense.set(i, j, 1.0);
            }
        }
    }
    let beta: Vec<f64> = (0..p).map(|j| if j < 5 { 2.0 } else { 0.0 }).collect();
    let mut eta = vec![0.0; n];
    dense.gemv(&beta, &mut eta);
    let y: Vec<f64> = eta.iter().enumerate().map(|(i, e)| e + 0.1 * ((i % 7) as f64 - 3.0)).collect();
    let mut csc = Csc::from_dense(&dense);
    csc.scale_columns();
    let ymean = slope_screen::linalg::ops::mean(&y);
    let yc: Vec<f64> = y.iter().map(|v| v - ymean).collect();
    let prob = slope_screen::slope::family::Problem::new(
        Design::Sparse(csc),
        yc,
        Family::Gaussian,
    );
    let o = opts(LambdaKind::Bh { q: 0.1 }, Strategy::StrongSet, 12);
    let fit = fit_path(&prob, &o, &NativeGradient(&prob));
    assert!(fit.steps.last().unwrap().n_active > 0);
}

/// Violations, when they occur, are safeguarded: the final solution of
/// every step still satisfies KKT. Use a stress configuration (coarse
/// grid, high correlation) to provoke them.
#[test]
fn violations_are_safeguarded() {
    use slope_screen::slope::subdiff::kkt_optimal;
    let prob = spec(40, 60, 15, 0.7, Family::Gaussian).generate(&mut Pcg64::new(12));
    let mut cfg = PathConfig::new(LambdaKind::Bh { q: 0.3 });
    cfg.length = 6; // very coarse grid => big λ gaps => more violations
    cfg = cfg.without_early_stopping();
    let o = PathOptions::new(cfg).with_strategy(Strategy::PreviousSet);
    let fit = fit_path(&prob, &o, &NativeGradient(&prob));
    for (m, &sig) in fit.sigmas.iter().enumerate().skip(1) {
        let beta = fit.beta_at(m, prob.p_total());
        let (_, grad) = prob.loss_grad(&beta);
        let lam: Vec<f64> = fit.lambda_base.iter().map(|l| l * sig).collect();
        assert!(
            kkt_optimal(&beta, &grad, &lam, 1e-3 * sig * fit.lambda_base[0]),
            "step {m} failed KKT after safeguard"
        );
    }
}

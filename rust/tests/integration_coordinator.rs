//! Integration: the CV coordinator and experiment grid over real path
//! fits — determinism, strategy equivalence at the model-selection level,
//! and the end-to-end workload in miniature.

use slope_screen::coordinator::{cross_validate, run_grid, CvConfig, GridSpec};
use slope_screen::data::real::RealDataset;
use slope_screen::data::synth::{BetaSpec, DesignKind, SyntheticSpec};
use slope_screen::rng::Pcg64;
use slope_screen::slope::family::Family;
use slope_screen::slope::lambda::{LambdaKind, PathConfig};
use slope_screen::slope::path::{PathOptions, Strategy};

fn toy_problem(seed: u64, n: usize, p: usize) -> slope_screen::slope::family::Problem {
    SyntheticSpec {
        n,
        p,
        rho: 0.2,
        design: DesignKind::Compound,
        beta: BetaSpec::PlusMinus { k: 5, scale: 2.0 },
        family: Family::Gaussian,
        noise_sd: 0.7,
        standardize: true,
    }
    .generate(&mut Pcg64::new(seed))
}

fn toy_opts(strategy: Strategy) -> PathOptions {
    let mut cfg = PathConfig::new(LambdaKind::Bh { q: 0.1 });
    cfg.length = 15;
    PathOptions::new(cfg).with_strategy(strategy)
}

/// Screening must not change model selection: CV curves agree between
/// strong-set and no-screening strategies.
#[test]
fn cv_model_selection_invariant_to_screening() {
    let prob = toy_problem(1, 60, 40);
    let cfg = CvConfig { folds: 4, repeats: 1, threads: 4, seed: 5 };
    let a = cross_validate(&prob, &toy_opts(Strategy::StrongSet), &cfg);
    let b = cross_validate(&prob, &toy_opts(Strategy::NoScreening), &cfg);
    assert_eq!(a.best_index, b.best_index);
    for (x, y) in a.mean_deviance.iter().zip(&b.mean_deviance) {
        assert!((x - y).abs() < 1e-5, "{x} vs {y}");
    }
}

/// Grid driver + CV compose: a miniature of the full experiment pipeline.
#[test]
fn grid_of_cv_runs() {
    let spec = GridSpec::new(vec!["rho=0.0".into(), "rho=0.5".into()], 2, 99);
    let results = run_grid(&spec, |gp| {
        let rho = if gp.label.contains("0.5") { 0.5 } else { 0.0 };
        let prob = SyntheticSpec {
            n: 40,
            p: 30,
            rho,
            design: DesignKind::Compound,
            beta: BetaSpec::PlusMinus { k: 3, scale: 2.0 },
            family: Family::Gaussian,
            noise_sd: 0.5,
            standardize: true,
        }
        .generate(&mut Pcg64::new(gp.seed));
        let cfg = CvConfig { folds: 3, repeats: 1, threads: 1, seed: gp.seed };
        let res = cross_validate(&prob, &toy_opts(Strategy::StrongSet), &cfg);
        res.mean_deviance[res.best_index]
    });
    assert_eq!(results.len(), 4);
    assert!(results.iter().all(|(_, v)| v.is_finite() && *v >= 0.0));
}

/// The golub end-to-end workload in miniature (shorter path) must select
/// a non-trivial model and run violation-free.
#[test]
fn golub_cv_miniature() {
    let prob = RealDataset::Golub.load();
    let mut cfg = PathConfig::new(LambdaKind::Bh { q: 0.01 });
    cfg.length = 25;
    let opts = PathOptions::new(cfg);
    let cv_cfg = CvConfig { folds: 3, repeats: 1, threads: 4, seed: 2020 };
    let res = cross_validate(&prob, &opts, &cv_cfg);
    assert_eq!(res.folds.len(), 3);
    assert!(res.best_index > 0, "CV should pick a non-null model");
    assert!(res.mean_deviance[res.best_index] < res.mean_deviance[0]);
}

/// Dataset stand-ins all load and fit a short screened path.
#[test]
fn all_real_standins_fit_short_paths() {
    use slope_screen::slope::path::{fit_path, NativeGradient};
    // gisette/dorothea excluded here for CI time; covered by benches.
    for ds in [RealDataset::Golub, RealDataset::Cpusmall, RealDataset::Physician, RealDataset::Zipcode] {
        let prob = ds.load();
        let mut cfg = PathConfig::new(LambdaKind::Bh { q: 0.05 });
        cfg.length = 8;
        let opts = PathOptions::new(cfg);
        let fit = fit_path(&prob, &opts, &NativeGradient(&prob));
        assert!(!fit.steps.is_empty(), "{} produced an empty path", ds.name());
        assert!(
            fit.steps.last().unwrap().n_active > 0,
            "{} never activated a predictor",
            ds.name()
        );
    }
}

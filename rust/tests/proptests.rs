//! Property tests over the paper's core invariants, using the in-crate
//! `check` harness (no proptest offline). These complement the unit-level
//! properties inside each module with *cross-module* laws.

use slope_screen::check::{all_close, ensure, forall, gen, Config};
use slope_screen::linalg::ops::{abs_sorted_desc, order_desc_abs};
use slope_screen::linalg::{Csc, Design, Mat, PackedDesign, ParConfig};
use slope_screen::rng::Pcg64;
use slope_screen::slope::prox::{prox_sorted_l1, prox_sorted_l1_reference};
use slope_screen::slope::screen::{algorithm1, algorithm2_k, strong_set};
use slope_screen::slope::sorted::{sl1_norm, support};
use slope_screen::slope::subdiff::{in_subdifferential, kkt_infeasibility};

/// Proposition 1: with the *true* gradient of the solution as input,
/// Algorithm 1 returns a superset of the support.
///
/// Construction: pick any β* and λ; by Theorem 1 there exist gradients g
/// with −g ∈ ∂J(β*; λ) — take the canonical one assigning λ-by-rank inside
/// each cluster. Algorithm 1 run on |g|↓ must keep every active index.
#[test]
fn prop1_algorithm1_covers_support() {
    forall(
        Config { cases: 400, seed: 0x201 },
        |rng| {
            let beta = gen::tied_vec(rng, 1, 25);
            let lam = gen::lambda_seq(rng, beta.len());
            (beta, lam)
        },
        |(beta, lam)| {
            // canonical subgradient: |g| = λ arranged by the rank of |β|,
            // sign matching β on active coords.
            let ord = order_desc_abs(beta);
            let mut g = vec![0.0; beta.len()];
            for (rank, &idx) in ord.iter().enumerate() {
                let sign = if beta[idx] != 0.0 { beta[idx].signum() } else { 1.0 };
                g[idx] = lam[rank] * sign;
            }
            // sanity: this g is a valid (negated) subgradient
            ensure(
                in_subdifferential(beta, &g, lam, 1e-9),
                "canonical subgradient invalid",
            )?;
            let k = algorithm2_k(&abs_sorted_desc(&g), lam);
            let kept: Vec<usize> = ord[..k].to_vec();
            for j in support(beta) {
                ensure(kept.contains(&j), format!("support index {j} discarded"))?;
            }
            Ok(())
        },
    );
}

/// Algorithm 1 and Algorithm 2 agree on every input (set version vs fast
/// version), and the screened set is always a prefix in rank order.
#[test]
fn algorithms_1_and_2_agree() {
    forall(
        Config { cases: 600, seed: 0x202 },
        |rng| {
            let mut c = gen::normal_vec(rng, 1, 50);
            for v in c.iter_mut() {
                *v = v.abs();
            }
            c.sort_by(|a, b| b.partial_cmp(a).unwrap());
            let lam = gen::lambda_seq(rng, c.len());
            (c, lam)
        },
        |(c, lam)| {
            let s = algorithm1(c, lam);
            let k = algorithm2_k(c, lam);
            ensure(s.len() == k, format!("|S|={} k={k}", s.len()))?;
            ensure(s.iter().copied().eq(0..k), "not a prefix")
        },
    );
}

/// The unit-slope bound (Prop. 2 mechanism): the strong-rule criterion
/// dominates the true next-step criterion whenever the gradient actually
/// moves slower than λ — so the strong set contains the exact
/// Algorithm-1 set computed from any such gradient.
#[test]
fn strong_rule_dominates_slow_gradients() {
    forall(
        Config { cases: 300, seed: 0x203 },
        |rng| {
            let p = 2 + rng.below(30) as usize;
            let g_prev = gen::normal_vec(rng, p, p);
            let lam_prev = gen::lambda_seq(rng, p);
            // next lambda: shrink by a random factor
            let shrink = 0.3 + 0.6 * rng.next_f64();
            let lam_next: Vec<f64> = lam_prev.iter().map(|l| l * shrink).collect();
            // a "unit slope" gradient move: |g_next − g_prev| ≤ λ_prev − λ_next
            // elementwise in rank order
            let ord = order_desc_abs(&g_prev);
            let mut g_next = g_prev.clone();
            for (rank, &idx) in ord.iter().enumerate() {
                let slack = (lam_prev[rank] - lam_next[rank]).abs();
                let delta = (2.0 * rng.next_f64() - 1.0) * slack;
                // perturb magnitude but keep ordering: shrink toward
                // preserving rank by moving |g| within its slack
                let mag = (g_prev[idx].abs() + delta).max(0.0);
                g_next[idx] = mag * if g_prev[idx] == 0.0 { 1.0 } else { g_prev[idx].signum() };
            }
            (g_prev, g_next, lam_prev, lam_next)
        },
        |(g_prev, g_next, lam_prev, lam_next)| {
            // Proposition 2 additionally assumes the ordering permutation
            // does not change; enforce it by skipping cases where it does.
            if order_desc_abs(g_prev) != order_desc_abs(g_next) {
                return Ok(());
            }
            let screened = strong_set(g_prev, lam_prev, lam_next);
            let exact_k = algorithm2_k(&abs_sorted_desc(g_next), lam_next);
            let exact: Vec<usize> = order_desc_abs(g_next)[..exact_k].to_vec();
            for j in exact {
                ensure(
                    screened.contains(&j),
                    format!("violation: predictor {j} outside the strong set"),
                )?;
            }
            Ok(())
        },
    );
}

/// Algorithm 1 and Algorithm 2 agree on inputs dense with exact ties and
/// zeros — in both the criterion *and* the penalty (λ with zero tails is
/// where the `cumsum ≥ 0` boundary is exercised hardest).
#[test]
fn algorithms_agree_on_tied_and_zero_inputs() {
    forall(
        Config { cases: 500, seed: 0x208 },
        |rng| {
            let mut c: Vec<f64> =
                slope_screen::check::gen::tied_vec(rng, 0, 30).iter().map(|v| v.abs()).collect();
            c.sort_by(|a, b| b.partial_cmp(a).unwrap());
            let mut lam: Vec<f64> = (0..c.len())
                .map(|_| {
                    if rng.bernoulli(0.3) {
                        0.0
                    } else {
                        (rng.next_f64() * 8.0).round() / 4.0
                    }
                })
                .collect();
            lam.sort_by(|a, b| b.partial_cmp(a).unwrap());
            (c, lam)
        },
        |(c, lam)| {
            let s = algorithm1(c, lam);
            let k = algorithm2_k(c, lam);
            ensure(s.len() == k, format!("|S|={} vs k={k}", s.len()))?;
            ensure(s.iter().copied().eq(0..k), "not a prefix")
        },
    );
}

/// Deterministic edge cases for the two screening algorithms: empty
/// input, everything discarded, everything kept, and ties at zero.
#[test]
fn algorithms_agree_on_edge_cases() {
    // empty
    assert!(algorithm1(&[], &[]).is_empty());
    assert_eq!(algorithm2_k(&[], &[]), 0);
    // all discarded
    let c = [0.5, 0.4, 0.1];
    let lam = [1.0, 0.9, 0.8];
    assert!(algorithm1(&c, &lam).is_empty());
    assert_eq!(algorithm2_k(&c, &lam), 0);
    // all kept
    let c = [2.0, 1.5, 1.2];
    assert_eq!(algorithm1(&c, &lam), vec![0, 1, 2]);
    assert_eq!(algorithm2_k(&c, &lam), 3);
    // zero criterion against zero penalty: the `≥ 0` boundary keeps all
    let c = [1.0, 0.0, 0.0];
    let lam0 = [0.0, 0.0, 0.0];
    assert_eq!(algorithm1(&c, &lam0), vec![0, 1, 2]);
    assert_eq!(algorithm2_k(&c, &lam0), 3);
    // zero tail against a positive penalty: only the head survives
    let lam1 = [0.5, 0.5, 0.0];
    assert_eq!(algorithm1(&c, &lam1), vec![0]);
    assert_eq!(algorithm2_k(&c, &lam1), 1);
}

/// The sorted-set algebra the path driver is built on, against a
/// `BTreeSet` oracle.
#[test]
fn set_algebra_matches_btreeset_oracle() {
    use slope_screen::slope::path::{diff_sorted, intersect_sorted, union_sorted};
    use std::collections::BTreeSet;
    forall(
        Config { cases: 500, seed: 0x209 },
        |rng| {
            let mut draw = |rng: &mut Pcg64| {
                let len = rng.below(20) as usize;
                let mut v: Vec<usize> = (0..len).map(|_| rng.below(30) as usize).collect();
                v.sort_unstable();
                v.dedup();
                v
            };
            let a = draw(&mut *rng);
            let b = draw(&mut *rng);
            (a, b)
        },
        |(a, b)| {
            let sa: BTreeSet<usize> = a.iter().copied().collect();
            let sb: BTreeSet<usize> = b.iter().copied().collect();
            let want_union: Vec<usize> = sa.union(&sb).copied().collect();
            let want_diff: Vec<usize> = sa.difference(&sb).copied().collect();
            let want_intersect: Vec<usize> = sa.intersection(&sb).copied().collect();
            ensure(union_sorted(a, b) == want_union, "union mismatch")?;
            ensure(diff_sorted(a, b) == want_diff, "difference mismatch")?;
            ensure(intersect_sorted(a, b) == want_intersect, "intersection mismatch")?;
            // identities the safeguard loop relies on
            ensure(union_sorted(a, a) == *a, "union not idempotent")?;
            ensure(diff_sorted(a, a).is_empty(), "self-difference not empty")?;
            ensure(intersect_sorted(a, &[]).is_empty(), "intersect with empty")
        },
    );
}

/// Prox firm-nonexpansiveness and decomposition: prox(v) + prox-residual
/// splits v, and the residual is a subgradient at the prox point.
#[test]
fn prox_moreau_decomposition_property() {
    forall(
        Config { cases: 300, seed: 0x204 },
        |rng| {
            let v = gen::tied_vec(rng, 1, 20);
            let lam = gen::lambda_seq(rng, v.len());
            (v, lam)
        },
        |(v, lam)| {
            let b = prox_sorted_l1(v, lam);
            let r: Vec<f64> = v.iter().zip(&b).map(|(vi, bi)| vi - bi).collect();
            // residual is in ∂J(b)
            ensure(in_subdifferential(&b, &r, lam, 1e-8), "residual not a subgradient")?;
            // and at zero-prox points, infeasibility of v itself is zero
            if b.iter().all(|&x| x == 0.0) {
                ensure(
                    kkt_infeasibility(v, lam) <= 1e-9,
                    "zero prox but v outside the dual ball",
                )?;
            }
            Ok(())
        },
    );
}

/// Fast prox ≡ reference prox on adversarial tied inputs.
#[test]
fn prox_implementations_agree() {
    forall(
        Config { cases: 400, seed: 0x205 },
        |rng| {
            let v = gen::tied_vec(rng, 1, 30);
            let lam = gen::lambda_seq(rng, v.len());
            (v, lam)
        },
        |(v, lam)| all_close(&prox_sorted_l1(v, lam), &prox_sorted_l1_reference(v, lam), 1e-10),
    );
}

/// The sorted-ℓ1 norm is a norm: triangle inequality, homogeneity, and
/// monotonicity in λ.
#[test]
fn sl1_norm_axioms() {
    forall(
        Config { cases: 300, seed: 0x206 },
        |rng| {
            let a = gen::normal_vec(rng, 2, 20);
            let b: Vec<f64> = a.iter().map(|_| rng.normal()).collect();
            let lam = gen::lambda_seq(rng, a.len());
            let t = rng.uniform(0.0, 3.0);
            (a, b, lam, t)
        },
        |(a, b, lam, t)| {
            let sum: Vec<f64> = a.iter().zip(b).map(|(x, y)| x + y).collect();
            let na = sl1_norm(a, lam);
            let nb = sl1_norm(b, lam);
            let ns = sl1_norm(&sum, lam);
            ensure(ns <= na + nb + 1e-9, format!("triangle: {ns} > {na} + {nb}"))?;
            let scaled: Vec<f64> = a.iter().map(|x| x * t).collect();
            ensure(
                (sl1_norm(&scaled, lam) - t * na).abs() <= 1e-9 * (1.0 + t * na),
                "homogeneity",
            )
        },
    );
}

/// The parallel linalg backend is a pure reformulation of the serial
/// kernels: `gemv`, `gemv_t`, `gemv_t_subset` and `col_sq_norms` must
/// agree to 1e-12 across thread counts {1, 2, 7} on dense and sparse
/// storage, including the degenerate shapes (n = 0, p = 1, p < threads)
/// where partitioning is trickiest. `ParConfig::exact` disables the
/// work-size floor so the parallel code path actually runs on these
/// small inputs.
#[test]
fn parallel_kernels_match_serial_across_thread_counts() {
    const SHAPES: &[(usize, usize)] = &[
        (0, 3),   // no observations
        (1, 1),   // scalar
        (4, 1),   // p = 1
        (3, 5),   // p < 7 threads
        (17, 9),  // odd sizes
        (24, 40), // p > n
        (64, 13),
    ];
    forall(
        Config { cases: 150, seed: 0x20b },
        |rng| {
            let (n, p) = SHAPES[rng.below(SHAPES.len() as u64) as usize];
            // ~30% structural zeros so the sparse path has real gaps
            let data: Vec<f64> = (0..n * p)
                .map(|_| if rng.bernoulli(0.3) { 0.0 } else { rng.normal() })
                .collect();
            let v: Vec<f64> = (0..p).map(|_| rng.normal()).collect();
            let w: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let cols: Vec<usize> = (0..p).filter(|_| rng.bernoulli(0.6)).collect();
            (n, p, data, v, w, cols)
        },
        |(n, p, data, v, w, cols)| {
            let (n, p) = (*n, *p);
            let dense = Mat::from_col_major(n, p, data.clone());
            let sparse = Csc::from_dense(&dense);
            let vc: Vec<f64> = cols.iter().map(|&j| v[j]).collect();

            // serial references
            let mut xv = vec![0.0; n];
            dense.gemv(v, &mut xv);
            let mut xtv = vec![0.0; p];
            dense.gemv_t(w, &mut xtv);
            let mut xtv_sub = vec![0.0; cols.len()];
            dense.gemv_t_subset(cols, w, &mut xtv_sub);
            let norms = dense.col_sq_norms();

            for threads in [1usize, 2, 7] {
                let par = ParConfig::exact(threads);
                let tag = |k: &str| format!("{k} (n={n}, p={p}, t={threads})");

                let mut out = vec![0.0; n];
                dense.gemv_with(v, &mut out, par);
                all_close(&out, &xv, 1e-12).map_err(|e| tag(&format!("dense gemv: {e}")))?;
                sparse.gemv_with(v, &mut out, par);
                all_close(&out, &xv, 1e-12).map_err(|e| tag(&format!("sparse gemv: {e}")))?;
                dense.gemv_subset_with(cols, &vc, &mut out, par);
                let mut sub_ref = vec![0.0; n];
                dense.gemv_subset(cols, &vc, &mut sub_ref);
                all_close(&out, &sub_ref, 1e-12)
                    .map_err(|e| tag(&format!("dense gemv_subset: {e}")))?;

                let mut gout = vec![0.0; p];
                dense.gemv_t_with(w, &mut gout, par);
                all_close(&gout, &xtv, 1e-12).map_err(|e| tag(&format!("dense gemv_t: {e}")))?;
                sparse.gemv_t_with(w, &mut gout, par);
                all_close(&gout, &xtv, 1e-12).map_err(|e| tag(&format!("sparse gemv_t: {e}")))?;

                let mut sout = vec![0.0; cols.len()];
                dense.gemv_t_subset_with(cols, w, &mut sout, par);
                all_close(&sout, &xtv_sub, 1e-12)
                    .map_err(|e| tag(&format!("dense gemv_t_subset: {e}")))?;
                sparse.gemv_t_subset_with(cols, w, &mut sout, par);
                all_close(&sout, &xtv_sub, 1e-12)
                    .map_err(|e| tag(&format!("sparse gemv_t_subset: {e}")))?;

                all_close(&dense.col_sq_norms_with(par), &norms, 1e-12)
                    .map_err(|e| tag(&format!("dense col_sq_norms: {e}")))?;
                all_close(&sparse.col_sq_norms_with(par), &norms, 1e-12)
                    .map_err(|e| tag(&format!("sparse col_sq_norms: {e}")))?;
            }
            Ok(())
        },
    );
}

/// The packed reduced-design engine is a pure reformulation of the
/// gather kernels: `PackedDesign::gemv(_t)` must agree with
/// `gemv_subset`/`gemv_t_subset` to 1e-12 on dense and sparse storage,
/// across thread counts {1, 2, 7}, including the degenerate shapes
/// (n = 0, p = 1) and subsets (∅, all columns) where slab partitioning
/// and packing are trickiest. On dense storage the agreement is in fact
/// bitwise (the packed kernels replicate the gather accumulation
/// orders); the shared 1e-12 bound also covers the sparse kernels, which
/// regroup sums when the slab streams structural zeros.
#[test]
fn packed_kernels_match_gather_kernels() {
    const SHAPES: &[(usize, usize)] = &[
        (0, 3),   // no observations
        (1, 1),   // scalar
        (4, 1),   // p = 1
        (3, 5),   // p < 7 threads
        (17, 9),  // odd sizes
        (24, 40), // p > n
        (64, 13),
    ];
    forall(
        Config { cases: 150, seed: 0x20d },
        |rng| {
            let (n, p) = SHAPES[rng.below(SHAPES.len() as u64) as usize];
            let data: Vec<f64> = (0..n * p)
                .map(|_| if rng.bernoulli(0.3) { 0.0 } else { rng.normal() })
                .collect();
            let w: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let cols: Vec<usize> = match rng.below(3) {
                0 => Vec::new(),               // subset = ∅
                1 => (0..p).collect(),         // subset = all
                _ => (0..p).filter(|_| rng.bernoulli(0.5)).collect(),
            };
            // ~25% exact zeros in the reduced iterate (screened-path case)
            let vc: Vec<f64> = cols
                .iter()
                .map(|_| if rng.bernoulli(0.25) { 0.0 } else { rng.normal() })
                .collect();
            (n, p, data, w, cols, vc)
        },
        |(n, p, data, w, cols, vc)| {
            let (n, p) = (*n, *p);
            let dense = Mat::from_col_major(n, p, data.clone());
            let designs =
                [Design::Dense(dense.clone()), Design::Sparse(Csc::from_dense(&dense))];
            for (di, design) in designs.iter().enumerate() {
                let kind = if di == 0 { "dense" } else { "sparse" };
                let mut want_ev = vec![0.0; n];
                design.gemv_subset(cols, vc, &mut want_ev);
                let mut want_gr = vec![0.0; cols.len()];
                design.gemv_t_subset(cols, w, &mut want_gr);
                for threads in [1usize, 2, 7] {
                    let par = ParConfig::exact(threads);
                    let pack = PackedDesign::pack(design, cols, par);
                    let tag = |k: &str, e: &str| {
                        format!("{kind} {k} (n={n}, p={p}, |E|={}, t={threads}): {e}", cols.len())
                    };
                    let mut ev = vec![0.0; n];
                    pack.gemv_with(vc, &mut ev, par);
                    all_close(&ev, &want_ev, 1e-12).map_err(|e| tag("gemv", &e))?;
                    let mut ev2 = vec![0.0; n];
                    pack.gemv(vc, &mut ev2);
                    ensure(ev == ev2, tag("gemv", "parallel != serial"))?;
                    let mut gr = vec![0.0; cols.len()];
                    pack.gemv_t_with(w, &mut gr, par);
                    all_close(&gr, &want_gr, 1e-12).map_err(|e| tag("gemv_t", &e))?;
                    let mut gr2 = vec![0.0; cols.len()];
                    pack.gemv_t(w, &mut gr2);
                    ensure(gr == gr2, tag("gemv_t", "parallel != serial"))?;
                }
            }
            Ok(())
        },
    );
}

/// Growing a pack incrementally (the KKT safeguard's violator admission)
/// is indistinguishable from packing the final set fresh: same ascending
/// column view, same kernel results — the merged traversal order makes
/// append history invisible.
#[test]
fn incremental_append_matches_fresh_pack() {
    forall(
        Config { cases: 120, seed: 0x20e },
        |rng| {
            let n = rng.below(25) as usize; // 0..=24 rows
            let p = 2 + rng.below(30) as usize;
            let data: Vec<f64> = (0..n * p)
                .map(|_| if rng.bernoulli(0.3) { 0.0 } else { rng.normal() })
                .collect();
            // random partition of a random subset into base + 3 batches
            let mut batches: Vec<Vec<usize>> = vec![Vec::new(); 4];
            let mut all: Vec<usize> = Vec::new();
            for c in 0..p {
                if rng.bernoulli(0.6) {
                    batches[rng.below(4) as usize].push(c);
                    all.push(c);
                }
            }
            let v: Vec<f64> = all.iter().map(|_| rng.normal()).collect();
            let w: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            (n, p, data, batches, all, v, w)
        },
        |(n, p, data, batches, all, v, w)| {
            let dense = Mat::from_col_major(*n, *p, data.clone());
            let designs =
                [Design::Dense(dense.clone()), Design::Sparse(Csc::from_dense(&dense))];
            for design in &designs {
                let mut inc = PackedDesign::pack(design, &batches[0], ParConfig::serial());
                for (bi, batch) in batches[1..].iter().enumerate() {
                    let par = if bi % 2 == 0 { ParConfig::exact(3) } else { ParConfig::serial() };
                    inc.append(design, batch, par);
                }
                let fresh = PackedDesign::pack(design, all, ParConfig::serial());
                ensure(inc.sorted_cols() == *all, "appended column view diverged")?;
                ensure(inc.ncols() == all.len(), "ncols diverged")?;
                let (mut a, mut b) = (vec![0.0; *n], vec![0.0; *n]);
                inc.gemv(v, &mut a);
                fresh.gemv(v, &mut b);
                ensure(a == b, "gemv: appended pack != fresh pack")?;
                let (mut c, mut d) = (vec![0.0; all.len()], vec![0.0; all.len()]);
                inc.gemv_t(w, &mut c);
                fresh.gemv_t(w, &mut d);
                ensure(c == d, "gemv_t: appended pack != fresh pack")?;
            }
            Ok(())
        },
    );
}

/// Parallel standardize agrees with serial standardize across thread
/// counts (dense center+scale; sparse unit-scaling).
#[test]
fn parallel_standardize_matches_serial() {
    forall(
        Config { cases: 80, seed: 0x20c },
        |rng| {
            let n = 1 + rng.below(24) as usize;
            let p = 1 + rng.below(15) as usize;
            let data: Vec<f64> = (0..n * p)
                .map(|_| if rng.bernoulli(0.25) { 0.0 } else { rng.normal() * 3.0 })
                .collect();
            (n, p, data)
        },
        |(n, p, data)| {
            let dense = Mat::from_col_major(*n, *p, data.clone());
            for threads in [1usize, 2, 7] {
                let par = ParConfig::exact(threads);
                let mut serial = dense.clone();
                serial.standardize(true, true);
                let mut parallel = dense.clone();
                parallel.standardize_with(true, true, par);
                all_close(serial.data(), parallel.data(), 1e-12)
                    .map_err(|e| format!("dense standardize t={threads}: {e}"))?;
                let mut s_serial = Csc::from_dense(&dense);
                s_serial.scale_columns();
                let mut s_par = Csc::from_dense(&dense);
                s_par.scale_columns_with(par);
                all_close(s_serial.to_dense().data(), s_par.to_dense().data(), 1e-12)
                    .map_err(|e| format!("sparse scale t={threads}: {e}"))?;
            }
            Ok(())
        },
    );
}

/// Export → ingest round-trips are *bitwise*: `write_csv` /
/// `write_svmlight` use shortest-round-trip float formatting, and the
/// readers (with `standardize` off) must reproduce exactly the matrix
/// and response that were written — dense through the CSV row filler,
/// sparse through the two-pass CSC builder (including trailing all-zero
/// columns recovered from the `p=` header hint).
#[test]
fn ingest_round_trips_exports_bitwise() {
    use slope_screen::data::real::{write_csv, write_svmlight};
    use slope_screen::ingest::{self, IngestOptions};
    use slope_screen::slope::family::{Family, Problem};
    use std::sync::atomic::{AtomicUsize, Ordering};
    static CASE: AtomicUsize = AtomicUsize::new(0);
    forall(
        Config { cases: 40, seed: 0x20d },
        |rng| {
            let n = 1 + rng.below(18) as usize;
            let p = 1 + rng.below(12) as usize;
            let data: Vec<f64> = (0..n * p)
                .map(|_| if rng.bernoulli(0.3) { 0.0 } else { rng.normal() * 2.5 })
                .collect();
            let y: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            (n, p, data, y)
        },
        |(n, p, data, y)| {
            let case = CASE.fetch_add(1, Ordering::Relaxed);
            let dense = Mat::from_col_major(*n, *p, data.clone());
            let raw = IngestOptions::default().with_standardize(false);
            // dense CSV
            let prob = Problem::new(Design::Dense(dense.clone()), y.clone(), Family::Gaussian);
            let path = std::env::temp_dir()
                .join(format!("slope-prop-rt-{}-{case}.csv", std::process::id()));
            write_csv(&prob, &path).map_err(|e| e.to_string())?;
            let ing = ingest::load_csv(&path, &raw).map_err(|e| format!("csv: {e}"))?;
            let _ = std::fs::remove_file(&path);
            let got = ing.problem.x.as_dense().ok_or("csv must ingest dense")?;
            ensure(
                got.data().iter().zip(dense.data()).all(|(a, b)| a.to_bits() == b.to_bits()),
                "CSV round-trip is not bitwise",
            )?;
            ensure(
                ing.problem.y.iter().zip(y).all(|(a, b)| a.to_bits() == b.to_bits()),
                "CSV response round-trip is not bitwise",
            )?;
            // sparse svmlight (the CSC two-pass builder)
            let csc = Csc::from_dense(&dense);
            let sprob = Problem::new(Design::Sparse(csc), y.clone(), Family::Gaussian);
            let path = std::env::temp_dir()
                .join(format!("slope-prop-rt-{}-{case}.svm", std::process::id()));
            write_svmlight(&sprob, &path).map_err(|e| e.to_string())?;
            let ing = ingest::load_svmlight(&path, &raw).map_err(|e| format!("svm: {e}"))?;
            let _ = std::fs::remove_file(&path);
            let back = match &ing.problem.x {
                Design::Sparse(s) => s.to_dense(),
                Design::Dense(_) => return Err("svmlight must ingest sparse".to_string()),
            };
            ensure(
                (back.nrows(), back.ncols()) == (*n, *p),
                format!("svm shape {}x{} != {n}x{p}", back.nrows(), back.ncols()),
            )?;
            ensure(
                back.data().iter().zip(dense.data()).all(|(a, b)| a.to_bits() == b.to_bits()),
                "svmlight round-trip is not bitwise",
            )?;
            ensure(
                ing.problem.y.iter().zip(y).all(|(a, b)| a.to_bits() == b.to_bits()),
                "svmlight response round-trip is not bitwise",
            )
        },
    );
}

/// A fit on an ingested dense export is bitwise identical to the fit on
/// the in-memory `Mat` it came from, across kernel thread counts — the
/// ingest pipeline adds no numeric noise, and the parallel dense
/// kernels keep their bitwise-determinism contract through it. Problem
/// sizes are chosen so `n·p` clears the parallel grain floor (the
/// kernels genuinely split).
#[test]
fn ingested_dense_fit_matches_in_memory_fit_bitwise_across_threads() {
    use slope_screen::data::real::write_csv;
    use slope_screen::ingest::{self, IngestOptions};
    use slope_screen::linalg::ops;
    use slope_screen::slope::family::{Family, Problem};
    use slope_screen::slope::lambda::{LambdaKind, PathConfig};
    use slope_screen::slope::path::{fit_path, NativeGradient, PathOptions};
    use std::sync::atomic::{AtomicUsize, Ordering};
    static CASE: AtomicUsize = AtomicUsize::new(0);
    forall(
        Config { cases: 6, seed: 0x20e },
        |rng| {
            let n = 50 + rng.below(20) as usize;
            let p = 560 + rng.below(80) as usize;
            let seed = rng.next_u64();
            (n, p, seed)
        },
        |&(n, p, seed)| {
            let case = CASE.fetch_add(1, Ordering::Relaxed);
            let mut rng = Pcg64::new(seed);
            let mut x = Mat::zeros(n, p);
            for j in 0..p {
                for i in 0..n {
                    x.set(i, j, rng.normal());
                }
            }
            x.standardize(true, true);
            let mut y = vec![0.0f64; n];
            let beta: Vec<f64> =
                (0..p).map(|j| if j < 5 { 2.0 * rng.sign() } else { 0.0 }).collect();
            x.gemv(&beta, &mut y);
            for v in y.iter_mut() {
                *v += 0.3 * rng.normal();
            }
            let mean = ops::mean(&y);
            for v in y.iter_mut() {
                *v -= mean;
            }
            let prob = Problem::new(Design::Dense(x), y, Family::Gaussian);
            let path = std::env::temp_dir()
                .join(format!("slope-prop-fit-{}-{case}.csv", std::process::id()));
            write_csv(&prob, &path).map_err(|e| e.to_string())?;
            let opts = IngestOptions::default()
                .with_family(Family::Gaussian)
                .with_standardize(false);
            let ing = ingest::load_csv(&path, &opts).map_err(|e| e.to_string())?;
            let _ = std::fs::remove_file(&path);
            let mut reference: Option<(usize, Vec<f64>)> = None;
            for threads in [1usize, 2, 7] {
                let mut cfg = PathConfig::new(LambdaKind::Bh { q: 0.1 });
                cfg.length = 6;
                let o = PathOptions::new(cfg).with_threads(threads);
                let a = fit_path(&prob, &o, &NativeGradient(&prob));
                let b = fit_path(&ing.problem, &o, &NativeGradient(&ing.problem));
                ensure(
                    a.total_violations == b.total_violations,
                    format!("t={threads}: violations {} vs {}", a.total_violations, b.total_violations),
                )?;
                ensure(
                    a.final_beta
                        .iter()
                        .zip(&b.final_beta)
                        .all(|(x, y)| x.to_bits() == y.to_bits()),
                    format!("t={threads}: ingested fit != in-memory fit bitwise"),
                )?;
                match &reference {
                    None => reference = Some((a.total_violations, a.final_beta.clone())),
                    Some((viol, beta_ref)) => {
                        ensure(
                            *viol == a.total_violations
                                && beta_ref
                                    .iter()
                                    .zip(&a.final_beta)
                                    .all(|(x, y)| x.to_bits() == y.to_bits()),
                            format!("t={threads}: fit differs across thread counts"),
                        )?;
                    }
                }
            }
            Ok(())
        },
    );
}

/// End-to-end invariant: for random small problems, the fitted path's
/// screened sets never (after the safeguard) miss an active predictor,
/// across both heuristic strategies.
#[test]
fn path_screening_never_loses_active_predictors() {
    use slope_screen::data::synth::{BetaSpec, DesignKind, SyntheticSpec};
    use slope_screen::slope::family::Family;
    use slope_screen::slope::lambda::{LambdaKind, PathConfig};
    use slope_screen::slope::path::{fit_path, NativeGradient, PathOptions, Strategy};
    forall(
        Config { cases: 12, seed: 0x207 },
        |rng| {
            let n = 20 + rng.below(30) as usize;
            let p = 30 + rng.below(60) as usize;
            let rho = rng.next_f64() * 0.8;
            (n, p, rho, rng.next_u64())
        },
        |&(n, p, rho, seed)| {
            let prob = SyntheticSpec {
                n,
                p,
                rho,
                design: DesignKind::Compound,
                beta: BetaSpec::PlusMinus { k: 4, scale: 2.0 },
                family: Family::Gaussian,
                noise_sd: 1.0,
                standardize: true,
            }
            .generate(&mut Pcg64::new(seed));
            for strategy in [Strategy::StrongSet, Strategy::PreviousSet] {
                let mut cfg = PathConfig::new(LambdaKind::Bh { q: 0.1 });
                cfg.length = 10;
                let opts = PathOptions::new(cfg).with_strategy(strategy);
                let fit = fit_path(&prob, &opts, &NativeGradient(&prob));
                for (m, s) in fit.steps.iter().enumerate() {
                    ensure(
                        s.n_fitted >= s.n_active,
                        format!("{} step {m}: fitted {} < active {}", strategy.name(), s.n_fitted, s.n_active),
                    )?;
                }
            }
            Ok(())
        },
    );
}

/// Cross-request batching (DESIGN.md §14): a coalesced `fit_point`
/// batch is bitwise identical to the sequential serialization it
/// replaces — both chained (the cache-enabled server's warm-start
/// store/read cycle, replayed here by hand) and independent
/// (cache-disabled) — across kernel thread counts, because a batch is
/// one job running its members in arrival order.
#[test]
fn fit_point_batch_matches_sequential_bitwise_across_threads() {
    use slope_screen::data::synth::{BetaSpec, DesignKind, SyntheticSpec};
    use slope_screen::slope::family::Family;
    use slope_screen::slope::lambda::{LambdaKind, PathConfig};
    use slope_screen::slope::path::{
        fit_point, fit_point_batch, zero_seed, NativeGradient, PathOptions, Strategy,
    };
    forall(
        Config { cases: 6, seed: 0x214 },
        |rng| {
            let n = 25 + rng.below(25) as usize;
            let p = 40 + rng.below(60) as usize;
            let rho = rng.next_f64() * 0.5;
            let members = 2 + rng.below(3) as usize;
            let ratios: Vec<f64> = (0..members).map(|_| 0.2 + 0.7 * rng.next_f64()).collect();
            let chain = rng.below(2) == 0;
            (n, p, rho, ratios, chain, rng.next_u64())
        },
        |(n, p, rho, ratios, chain, seed)| {
            let prob = SyntheticSpec {
                n: *n,
                p: *p,
                rho: *rho,
                design: DesignKind::Compound,
                beta: BetaSpec::PlusMinus { k: 4, scale: 2.0 },
                family: Family::Gaussian,
                noise_sd: 1.0,
                standardize: true,
            }
            .generate(&mut Pcg64::new(*seed));
            let grad = NativeGradient(&prob);
            for threads in [1usize, 2, 7] {
                let mut cfg = PathConfig::new(LambdaKind::Bh { q: 0.1 });
                cfg.length = 8;
                let opts_first = PathOptions::new(cfg.clone())
                    .with_strategy(Strategy::StrongSet)
                    .with_threads(threads);
                let opts_rest = PathOptions::new(cfg)
                    .with_strategy(Strategy::PreviousSet)
                    .with_threads(threads);
                let seed0 = zero_seed(&prob, &opts_first, &grad);
                let sigmas: Vec<f64> = ratios.iter().map(|r| seed0.sigma * r).collect();
                // Sequential reference: one request at a time, item k+1
                // warm-started from the state item k stored (chain), or
                // every item cold from the shared seed (no cache).
                let mut cur = seed0.clone();
                let mut reference = Vec::new();
                for (k, &sigma) in sigmas.iter().enumerate() {
                    let o = if *chain && k > 0 { &opts_rest } else { &opts_first };
                    let fit =
                        fit_point(&prob, o, &grad, sigma, if *chain { &cur } else { &seed0 });
                    if *chain {
                        cur = fit.seed();
                    }
                    reference.push(fit);
                }
                let batch = fit_point_batch(
                    &prob, &opts_first, &opts_rest, &grad, &seed0, &sigmas, *chain,
                );
                ensure(batch.len() == reference.len(), "batch length")?;
                for (k, (b, r)) in batch.iter().zip(&reference).enumerate() {
                    let label = format!("t={threads} member {k} chain={chain}");
                    ensure(
                        b.beta.iter().zip(&r.beta).all(|(x, y)| x.to_bits() == y.to_bits()),
                        format!("{label}: beta drifted"),
                    )?;
                    ensure(
                        b.grad.iter().zip(&r.grad).all(|(x, y)| x.to_bits() == y.to_bits()),
                        format!("{label}: gradient drifted"),
                    )?;
                    ensure(
                        b.violations == r.violations
                            && b.n_active == r.n_active
                            && b.n_fitted == r.n_fitted
                            && b.solver_iterations == r.solver_iterations
                            && b.solver_converged == r.solver_converged,
                        format!("{label}: counters drifted"),
                    )?;
                }
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------
// checkpoint codec (DESIGN.md §13)
// ---------------------------------------------------------------------

/// A structurally-plausible snapshot with adversarial float content:
/// signed zeros, subnormal-adjacent values, infinities and NaN payloads
/// all have to survive the trip, because β/gradient buffers can carry
/// any of them after an overflowing solve.
fn random_snapshot(rng: &mut Pcg64) -> slope_screen::slope::checkpoint::Snapshot {
    use slope_screen::slope::checkpoint::{GapSnap, Snapshot, StepRec};
    const SPECIALS: [f64; 9] = [
        0.0,
        -0.0,
        f64::MIN_POSITIVE,
        f64::MAX,
        f64::INFINITY,
        f64::NEG_INFINITY,
        f64::NAN,
        1e-300,
        -3.25,
    ];
    fn val(rng: &mut Pcg64) -> f64 {
        if rng.below(4) == 0 {
            SPECIALS[rng.below(SPECIALS.len() as u64) as usize]
        } else {
            4.0 * rng.next_f64() - 2.0
        }
    }
    fn vec(rng: &mut Pcg64, len: usize) -> Vec<f64> {
        (0..len).map(|_| val(rng)).collect()
    }
    let pt = 1 + rng.below(40) as usize;
    let nm = 1 + rng.below(30) as usize;
    let n_done = 1 + rng.below(6) as usize;
    let gap_driven = rng.below(2) == 0;
    let steps: Vec<StepRec> = (0..n_done)
        .map(|i| StepRec {
            sigma: val(rng),
            n_active: rng.below(pt as u64 + 1),
            n_screened_rule: rng.below(pt as u64 + 1),
            n_fitted: rng.below(pt as u64 + 1),
            n_safe: gap_driven.then(|| rng.below(pt as u64 + 1)),
            violations: rng.below(4),
            refits: 1 + rng.below(3),
            solver_iterations: rng.below(500),
            deviance: val(rng),
            dev_ratio: rng.next_f64(),
            t_screen: rng.next_f64(),
            t_solve: rng.next_f64(),
            t_kkt: rng.next_f64(),
            solver_converged: rng.below(8) != 0,
            full_grad_sweeps: rng.next_f64() * 3.0,
            n_universe: gap_driven.then(|| rng.below(pt as u64 + 1)),
            gap: gap_driven.then(|| rng.next_f64()),
            degraded_to: (i == n_done - 1 && rng.below(4) == 0)
                .then(|| "previous".to_string()),
        })
        .collect();
    Snapshot {
        dataset_fp: rng.next_u64(),
        problem_fp: rng.next_u64(),
        grid_fp: rng.next_u64(),
        strategy: ["strong", "hybrid", "safe", "previous", "none"]
            [rng.below(5) as usize]
            .to_string(),
        next_step: n_done as u64,
        pt: pt as u64,
        nm: nm as u64,
        beta: vec(rng, pt),
        grad: vec(rng, pt),
        eta: vec(rng, nm),
        h: vec(rng, nm),
        total_violations: rng.below(10),
        total_grad_sweeps: rng.next_f64() * 10.0,
        sigmas: vec(rng, n_done),
        betas: (0..n_done)
            .map(|_| {
                let nnz = rng.below(pt as u64 + 1) as usize;
                (0..nnz).map(|j| (j as u64, val(rng))).collect()
            })
            .collect(),
        steps,
        gap: gap_driven.then(|| GapSnap {
            ref_h: vec(rng, nm),
            ref_gmag: vec(rng, pt),
            grad_bound: vec(rng, pt),
            loss: val(rng),
            grad_is_exact: rng.below(2) == 0,
        }),
    }
}

/// Encode → decode → re-encode is the identity on the byte level, which
/// is the strongest statement of bitwise fidelity (NaN payloads and -0.0
/// included — `PartialEq` on floats cannot express it).
#[test]
fn checkpoint_roundtrip_is_bitwise() {
    use slope_screen::slope::checkpoint::Snapshot;
    forall(
        Config { cases: 200, seed: 0xC4_01 },
        random_snapshot,
        |snap| {
            let bytes = snap.to_bytes();
            let back = Snapshot::from_bytes(&bytes).map_err(|e| format!("decode failed: {e}"))?;
            ensure(back.to_bytes() == bytes, "re-encode drifted from the original bytes")?;
            ensure(
                back.beta.iter().zip(&snap.beta).all(|(a, b)| a.to_bits() == b.to_bits()),
                "beta bits drifted",
            )
        },
    );
}

/// Cutting a snapshot anywhere — header, payload, digest — is a typed
/// error, never a panic and never a silently-decoded prefix.
#[test]
fn checkpoint_truncation_is_always_a_typed_error() {
    use slope_screen::slope::checkpoint::Snapshot;
    forall(
        Config { cases: 200, seed: 0xC4_02 },
        |rng| {
            let snap = random_snapshot(rng);
            let bytes = snap.to_bytes();
            let cut = rng.below(bytes.len() as u64) as usize;
            (bytes, cut)
        },
        |(bytes, cut)| match Snapshot::from_bytes(&bytes[..*cut]) {
            Err(e) => ensure(!e.kind().is_empty(), "error must carry a kind"),
            Ok(_) => Err(format!("truncation to {cut} of {} decoded", bytes.len())),
        },
    );
}

/// Flipping any bit of the magic, payload or digest is a typed error:
/// the digest covers the payload, and the magic gate covers itself. (The
/// version/length header fields are exercised by the unit-level golden
/// fixtures in `slope::checkpoint`.)
#[test]
fn checkpoint_bit_flips_are_always_typed_errors() {
    use slope_screen::slope::checkpoint::Snapshot;
    forall(
        Config { cases: 300, seed: 0xC4_03 },
        |rng| {
            let snap = random_snapshot(rng);
            let mut bytes = snap.to_bytes();
            // byte index within magic [0, 8) or payload+digest [20, len)
            let idx = if rng.below(4) == 0 {
                rng.below(8) as usize
            } else {
                20 + rng.below(bytes.len() as u64 - 20) as usize
            };
            let bit = rng.below(8) as u8;
            bytes[idx] ^= 1 << bit;
            (bytes, idx)
        },
        |(bytes, idx)| match Snapshot::from_bytes(bytes) {
            Err(e) => ensure(!e.kind().is_empty(), "error must carry a kind"),
            Ok(_) => Err(format!("bit flip at byte {idx} went undetected")),
        },
    );
}

/// Replication/replay contract (DESIGN.md §15): applying a journal
/// record stream is idempotent and prefix-stable. A standby that loses
/// its connection mid-catch-up re-subscribes and replays a snapshot
/// overlapping what it already applied — the overlap must be harmless.
/// One-shot replay of the full stream and "prefix, then re-replay from
/// an earlier point" must land in exactly the same registry state:
/// datasets intern once, strikes and seeds are last-record-wins, epochs
/// max-merge.
#[test]
fn journal_replay_is_idempotent_and_prefix_stable() {
    use slope_screen::jsonio::Json;
    use slope_screen::serve::registry::Registry;

    fn dataset_record(seed: u64) -> Json {
        Json::obj(vec![
            ("kind", Json::Str("dataset".to_string())),
            (
                "spec",
                Json::obj(vec![
                    ("kind", Json::Str("synth".to_string())),
                    ("n", Json::Num(12.0)),
                    ("p", Json::Num(10.0)),
                    ("k", Json::Num(2.0)),
                    ("rho", Json::Num(0.1)),
                    ("design", Json::Str("compound".to_string())),
                    ("family", Json::Str("gaussian".to_string())),
                    ("classes", Json::Num(3.0)),
                    ("seed", Json::Num(seed as f64)),
                ]),
            ),
        ])
    }

    forall(
        Config { cases: 60, seed: 0x5EED_10 },
        |rng| {
            let fps = ["00000000000000aa", "00000000000000bb", "00000000000000cc"];
            let len = 5 + rng.below(15) as usize;
            let mut records = Vec::with_capacity(len);
            for _ in 0..len {
                let rec = match rng.below(4) {
                    // interning the same tiny synth spec repeatedly is
                    // the idempotence case for datasets
                    0 => dataset_record(rng.below(2)),
                    1 => Json::obj(vec![
                        ("kind", Json::Str("strikes".to_string())),
                        ("fp", Json::Str(fps[rng.below(3) as usize].to_string())),
                        ("count", Json::Num(rng.below(4) as f64)),
                    ]),
                    2 => {
                        let dim = 1 + rng.below(5) as usize;
                        let beta: Vec<f64> =
                            (0..dim).map(|_| (rng.below(2001) as f64) / 500.0 - 2.0).collect();
                        let grad: Vec<f64> =
                            (0..dim).map(|_| (rng.below(2001) as f64) / 500.0 - 2.0).collect();
                        Json::obj(vec![
                            ("kind", Json::Str("model".to_string())),
                            ("fp", Json::Str(fps[rng.below(3) as usize].to_string())),
                            ("key", Json::Str(format!("bh-q{}", rng.below(3)))),
                            ("sigma", Json::Num((1 + rng.below(9)) as f64 / 10.0)),
                            ("beta", Json::nums(&beta)),
                            ("grad", Json::nums(&grad)),
                        ])
                    }
                    _ => Json::obj(vec![
                        ("kind", Json::Str("epoch".to_string())),
                        ("epoch", Json::Num(rng.below(9) as f64)),
                    ]),
                };
                records.push(rec);
            }
            let split = rng.below(len as u64 + 1) as usize;
            let dup_from = rng.below(split as u64 + 1) as usize;
            (records, split, dup_from)
        },
        |(records, split, dup_from)| {
            let render = |r: &Registry| {
                r.snapshot_records().iter().map(Json::to_string).collect::<Vec<_>>().join("\n")
            };
            let oneshot = Registry::new(true);
            for rec in records {
                oneshot.apply_replicated(rec);
            }
            let resumed = Registry::new(true);
            for rec in &records[..*split] {
                resumed.apply_replicated(rec);
            }
            // The re-subscription replays from before the cut: every
            // record in [dup_from, split) applies a second time.
            for rec in &records[*dup_from..] {
                resumed.apply_replicated(rec);
            }
            ensure(
                render(&oneshot) == render(&resumed),
                format!(
                    "replay diverged (split {split}, dup from {dup_from}):\n\
                     --- one-shot ---\n{}\n--- resumed ---\n{}",
                    render(&oneshot),
                    render(&resumed)
                ),
            )
        },
    );
}

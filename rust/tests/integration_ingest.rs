//! Ingest subsystem integration tests: golden fixtures, the
//! export → ingest → fit differential gate over the seven paper
//! stand-ins, and the serve `dataset_from_file` path.
//!
//! Numeric contracts (DESIGN.md §9): exports use shortest-round-trip
//! float formatting, so ingesting an export with `standardize` off
//! reproduces the design **bitwise** — same-storage fit comparisons are
//! exact and asserted at ≤1e-10 with exact violation counts. Dense and
//! sparse storage of the *same* data round differently in the kernels
//! (different summation orders), so cross-storage comparisons are
//! asserted at solver level, mirroring
//! `packed_engine_matches_gather_engine_sparse_to_tolerance`.

use std::path::{Path, PathBuf};
use std::sync::atomic::AtomicU64;

use slope_screen::data::real::{write_csv, write_svmlight, RealDataset};
use slope_screen::ingest::{self, IngestError, IngestOptions, YCol};
use slope_screen::jsonio::Json;
use slope_screen::linalg::{Csc, Design, Mat};
use slope_screen::rng::Pcg64;
use slope_screen::serve::protocol::{self, DatasetSpec};
use slope_screen::serve::registry::{CachedModel, Registry};
use slope_screen::serve::{Server, ServerConfig};
use slope_screen::slope::family::{sigmoid, Family, Problem};
use slope_screen::slope::lambda::{LambdaKind, PathConfig};
use slope_screen::slope::path::{
    fit_path, fit_point, zero_seed, NativeGradient, PathOptions, Strategy,
};

fn fixture(name: &str) -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures").join(name)
}

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("slope-ingest-it-{}-{name}", std::process::id()))
}

/// Ingest options for files already in model coordinates.
fn raw(family: Family) -> IngestOptions {
    IngestOptions::default().with_family(family).with_standardize(false)
}

// --- golden fixtures -----------------------------------------------------

#[test]
fn fixture_dense_header_quoting_crlf() {
    // CRLF endings, a comment line, a blank line, quoted fields with an
    // embedded comma, quoted numerics — the parsed matrix is pinned.
    let ing = ingest::load_csv(&fixture("dense_header.csv"), &raw(Family::Gaussian)).unwrap();
    let prob = &ing.problem;
    assert_eq!((prob.n(), prob.p()), (3, 2));
    let x = prob.x.as_dense().unwrap();
    let expect = Mat::from_rows(&[&[1.5, 2.0], &[3.0, -4.25], &[-0.5, 6.5]]);
    assert_eq!(x, &expect);
    assert_eq!(prob.y, vec![0.5, 1.0, 0.0]);
    assert_eq!(ing.format, ingest::Format::Csv);
    assert!(ing.stats.is_none());
}

#[test]
fn fixture_dense_noheader_and_y_first() {
    let ing = ingest::load_csv(&fixture("dense_noheader.csv"), &raw(Family::Gaussian)).unwrap();
    let x = ing.problem.x.as_dense().unwrap().clone();
    assert_eq!(x, Mat::from_rows(&[&[1.0, 2.0], &[4.0, 5.0]]));
    assert_eq!(ing.problem.y, vec![3.0, 6.0]);
    // the response column is configurable
    let opts = raw(Family::Gaussian).with_y_col(YCol::First);
    let ing = ingest::load_csv(&fixture("dense_noheader.csv"), &opts).unwrap();
    let x = ing.problem.x.as_dense().unwrap().clone();
    assert_eq!(x, Mat::from_rows(&[&[2.0, 3.0], &[5.0, 6.0]]));
    assert_eq!(ing.problem.y, vec![1.0, 4.0]);
}

#[test]
fn fixture_ragged_rows_rejected() {
    match ingest::load_csv(&fixture("ragged.csv"), &raw(Family::Gaussian)) {
        Err(IngestError::Structure { line: 2, msg }) => {
            assert!(msg.contains("2 fields, expected 3"), "msg: {msg}")
        }
        other => panic!("expected Structure at line 2, got {other:?}"),
    }
}

#[test]
fn fixture_nonfinite_csv_rejected() {
    // `nan` parses as a valid f64 — it must still be refused.
    match ingest::load_csv(&fixture("nonfinite.csv"), &raw(Family::Gaussian)) {
        Err(IngestError::NonFinite { line: 2, .. }) => {}
        other => panic!("expected NonFinite at line 2, got {other:?}"),
    }
}

#[test]
fn fixture_svmlight_golden() {
    // Header `p=5` hint (two trailing all-zero columns), an inline
    // comment, a blank line, and a bare-label row with no features.
    let ing = ingest::load_svmlight(&fixture("tiny.svm"), &raw(Family::Binomial)).unwrap();
    let prob = &ing.problem;
    assert_eq!((prob.n(), prob.p()), (3, 5));
    assert_eq!(prob.y, vec![1.0, 0.0, 1.0]);
    match &prob.x {
        Design::Sparse(csc) => {
            assert_eq!(csc.nnz(), 3);
            let expect = Mat::from_rows(&[
                &[0.5, 0.0, 0.0, -2.0, 0.0],
                &[0.0, 1.25, 0.0, 0.0, 0.0],
                &[0.0, 0.0, 0.0, 0.0, 0.0],
            ]);
            assert_eq!(csc.to_dense(), expect);
        }
        other => panic!("svmlight must build sparse, got {other:?}"),
    }
    assert_eq!(ing.format, ingest::Format::Svmlight);
}

#[test]
fn fixture_svmlight_duplicate_and_out_of_order_indices_rejected() {
    for name in ["dup_index.svm", "unordered.svm"] {
        match ingest::load_svmlight(&fixture(name), &raw(Family::Binomial)) {
            Err(IngestError::Structure { line: 1, msg }) => {
                assert!(msg.contains("strictly increasing"), "{name}: {msg}")
            }
            other => panic!("{name}: expected Structure at line 1, got {other:?}"),
        }
    }
}

#[test]
fn fixture_svmlight_nonfinite_rejected() {
    match ingest::load_svmlight(&fixture("nonfinite.svm"), &raw(Family::Gaussian)) {
        Err(IngestError::NonFinite { line: 1, .. }) => {}
        other => panic!("expected NonFinite at line 1, got {other:?}"),
    }
}

#[test]
fn fixture_like_negative_labels_map_to_zero() {
    // Classic svmlight ±1 labels ingest as 0/1 under binomial.
    let path = tmp("pm1.svm");
    std::fs::write(&path, "-1 1:2\n1 2:1\n").unwrap();
    let ing = ingest::load_svmlight(&path, &raw(Family::Binomial)).unwrap();
    assert_eq!(ing.problem.y, vec![0.0, 1.0]);
    let _ = std::fs::remove_file(&path);
}

#[test]
fn fixture_like_huge_index_is_a_typed_error_not_an_allocation() {
    // One malformed token must not abort the process on a terabyte
    // counts allocation — fatal for the fit server.
    let path = tmp("huge.svm");
    std::fs::write(&path, "1 999999999999:1\n").unwrap();
    match ingest::load_svmlight(&path, &raw(Family::Binomial)) {
        Err(IngestError::Structure { line: 1, msg }) => {
            assert!(msg.contains("feature cap"), "msg: {msg}")
        }
        other => panic!("expected Structure at line 1, got {other:?}"),
    }
    // an explicit n_features is the bound instead
    std::fs::write(&path, "1 5:1\n").unwrap();
    let opts = raw(Family::Binomial).with_n_features(3);
    match ingest::load_svmlight(&path, &opts) {
        Err(IngestError::Structure { line: 1, msg }) => {
            assert!(msg.contains("n_features"), "msg: {msg}")
        }
        other => panic!("expected Structure at line 1, got {other:?}"),
    }
    // a huge header hint is refused the same way
    std::fs::write(&path, "# p=999999999999\n1 1:1\n").unwrap();
    assert!(matches!(
        ingest::load_svmlight(&path, &raw(Family::Binomial)),
        Err(IngestError::Structure { line: 1, .. })
    ));
    let _ = std::fs::remove_file(&path);
}

#[test]
fn fixture_like_trailing_comment_p_hint_is_ignored() {
    // Only full-line header comments may declare p — a stray `p=<N>` in
    // a data line's trailing comment must not widen the design.
    let path = tmp("hint.svm");
    std::fs::write(&path, "1 1:0.5 # subsampled from p=999\n0 2:1\n").unwrap();
    let ing = ingest::load_svmlight(&path, &raw(Family::Binomial)).unwrap();
    assert_eq!(ing.problem.p(), 2, "trailing-comment hint must be ignored");
    let _ = std::fs::remove_file(&path);
}

#[test]
fn standardize_routes_through_parallel_backend_and_records_transform() {
    let path = tmp("std.csv");
    std::fs::write(&path, "x1,x2,y\n1,10,101\n2,20,102\n3,60,103\n").unwrap();
    let opts = IngestOptions::default(); // gaussian, standardize on
    let ing = ingest::load_csv(&path, &opts).unwrap();
    let x = ing.problem.x.as_dense().unwrap();
    for j in 0..2 {
        let col = x.col(j);
        let mean: f64 = col.iter().sum::<f64>() / 3.0;
        let norm: f64 = col.iter().map(|v| v * v).sum::<f64>().sqrt();
        assert!(mean.abs() < 1e-12 && (norm - 1.0).abs() < 1e-12);
    }
    // gaussian y centered, offset recorded
    assert!((ing.intercept - 102.0).abs() < 1e-12);
    assert!(ing.problem.y.iter().sum::<f64>().abs() < 1e-12);
    // the recorded transform maps raw rows onto the fitted design bitwise
    let stats = ing.stats.as_ref().unwrap();
    let raw_rows = [[1.0, 10.0], [2.0, 20.0], [3.0, 60.0]];
    for (i, row) in raw_rows.iter().enumerate() {
        for j in 0..2 {
            let mapped = (row[j] - stats.means[j]) * stats.inv_norms[j];
            assert_eq!(mapped, x.get(i, j), "row {i} col {j}");
        }
    }
    let _ = std::fs::remove_file(&path);
}

#[test]
fn two_pass_mismatch_is_detected() {
    // A second pass over different bytes must not mis-assemble silently.
    // Simulate by handing the reader a file, ingesting OK, then checking
    // the fingerprint tracks content (the in-run Changed guard itself is
    // exercised by both loaders' hash comparison on every ingest).
    let path = tmp("fp.csv");
    std::fs::write(&path, "x1,y\n1,2\n").unwrap();
    let a = ingest::load_csv(&path, &raw(Family::Gaussian)).unwrap().fingerprint;
    std::fs::write(&path, "x1,y\n1,3\n").unwrap();
    let b = ingest::load_csv(&path, &raw(Family::Gaussian)).unwrap().fingerprint;
    assert_ne!(a, b);
    let _ = std::fs::remove_file(&path);
}

// --- the differential gate ----------------------------------------------

/// Acceptance gate: for each of the seven stand-ins, export → ingest →
/// `fit_path` must match the in-memory fit — violations exact,
/// coefficients ≤ 1e-10 (the ingested design is bitwise identical, so
/// the fits are too; the tolerance is pure headroom). Dorothea runs
/// sparse through the two-pass CSC builder. Path lengths are bounded per
/// dataset to keep the gate test-sized — the equality under test is
/// configuration-independent.
#[test]
fn differential_gate_export_ingest_fit_matches_in_memory() {
    let dir = std::env::temp_dir().join(format!("slope-gate-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let cases: &[(RealDataset, usize)] = &[
        (RealDataset::Arcene, 6),
        (RealDataset::Dorothea, 5),
        (RealDataset::Gisette, 3),
        (RealDataset::Golub, 6),
        (RealDataset::Cpusmall, 8),
        (RealDataset::Physician, 6),
        (RealDataset::Zipcode, 5),
    ];
    for &(ds, len) in cases {
        let prob = ds.load();
        let family = prob.family;
        let was_sparse = matches!(prob.x, Design::Sparse(_));
        let path = ds.export_problem(&prob, &dir).unwrap();
        let mut cfg = PathConfig::new(LambdaKind::Bh { q: 0.1 });
        cfg.length = len;
        let opts = PathOptions::new(cfg);
        let a = fit_path(&prob, &opts, &NativeGradient(&prob));
        drop(prob); // gisette-scale: keep one design in memory at a time
        let ing = ingest::load_path(&path, &raw(family))
            .unwrap_or_else(|e| panic!("{}: ingest: {e}", ds.name()));
        assert_eq!(
            matches!(ing.problem.x, Design::Sparse(_)),
            was_sparse,
            "{}: storage class changed through export/ingest",
            ds.name()
        );
        let b = fit_path(&ing.problem, &opts, &NativeGradient(&ing.problem));
        assert_eq!(a.sigmas.len(), b.sigmas.len(), "{}: path lengths differ", ds.name());
        assert_eq!(
            a.total_violations,
            b.total_violations,
            "{}: violation totals differ",
            ds.name()
        );
        for (m, (sa, sb)) in a.steps.iter().zip(&b.steps).enumerate() {
            assert_eq!(sa.violations, sb.violations, "{} step {m}", ds.name());
            assert_eq!(sa.n_active, sb.n_active, "{} step {m}", ds.name());
            assert_eq!(sa.n_screened_rule, sb.n_screened_rule, "{} step {m}", ds.name());
        }
        let worst = a
            .final_beta
            .iter()
            .zip(&b.final_beta)
            .map(|(x, y)| (x - y).abs())
            .fold(0.0f64, f64::max);
        assert!(worst <= 1e-10, "{}: max coefficient delta {worst}", ds.name());
        let _ = std::fs::remove_file(&path);
    }
    let _ = std::fs::remove_dir(&dir);
}

// --- serve: dataset_from_file -------------------------------------------

/// A dorothea-textured miniature: sparse binary features from latent
/// groups, binomial response, columns pre-scaled to unit norm (model
/// coordinates, so every route ingests identical values).
fn mini_dorothea(seed: u64) -> Problem {
    let mut rng = Pcg64::new(seed);
    let (n, p, k) = (60usize, 150usize, 6usize);
    let r = 8;
    let groups: Vec<Vec<bool>> =
        (0..r).map(|_| (0..n).map(|_| rng.bernoulli(0.15)).collect()).collect();
    let mut cols: Vec<Vec<(usize, f64)>> = Vec::with_capacity(p);
    for _ in 0..p {
        let grp = &groups[rng.below(r as u64) as usize];
        let mut col = Vec::new();
        for (i, &g) in grp.iter().enumerate() {
            let on = if g { 0.4 } else { 0.03 };
            if rng.bernoulli(on) {
                col.push((i, 1.0));
            }
        }
        cols.push(col);
    }
    let mut eta = vec![0.0f64; n];
    for col in cols.iter().take(k) {
        let w = 1.5 * rng.sign();
        for &(i, v) in col {
            eta[i] += w * v;
        }
    }
    let mut y: Vec<f64> = eta
        .iter()
        .map(|&e| if rng.bernoulli(sigmoid(e - 0.2)) { 1.0 } else { 0.0 })
        .collect();
    // both classes present regardless of the draw
    y[0] = 0.0;
    y[1] = 1.0;
    let mut csc = Csc::from_columns(n, &cols);
    csc.scale_columns();
    Problem::new(Design::Sparse(csc), y, Family::Binomial)
}

fn parse_ok(response: &str) -> Json {
    let j = Json::parse(response).unwrap();
    assert_eq!(j.field("ok"), Some(&Json::Bool(true)), "expected success: {response}");
    j.field("result").unwrap().clone()
}

fn file_dataset_json(path: &Path) -> Json {
    Json::obj(vec![
        ("kind", Json::Str("file".to_string())),
        ("path", Json::Str(path.to_str().unwrap().to_string())),
        ("family", Json::Str("binomial".to_string())),
        ("standardize", Json::Bool(false)),
    ])
}

fn inline_dataset_json(prob: &Problem) -> Json {
    let dense = match &prob.x {
        Design::Sparse(csc) => csc.to_dense(),
        Design::Dense(m) => m.clone(),
    };
    let rows: Vec<Json> = (0..dense.nrows())
        .map(|i| Json::nums(&(0..dense.ncols()).map(|j| dense.get(i, j)).collect::<Vec<f64>>()))
        .collect();
    Json::obj(vec![
        ("kind", Json::Str("inline".to_string())),
        ("x", Json::Arr(rows)),
        ("y", Json::nums(&prob.y)),
        ("family", Json::Str("binomial".to_string())),
        ("standardize", Json::Bool(false)),
    ])
}

#[test]
fn serve_dataset_from_file_fit_matches_inline_and_in_memory() {
    let prob = mini_dorothea(0xd0a);
    let file = tmp("mini-dorothea.svm");
    write_svmlight(&prob, &file).unwrap();
    // fit_threads = 1 pins the kernels to their serial (bitwise
    // reference) forms, so the in-process replica below is exact.
    let srv = Server::new(ServerConfig { threads: 2, queue: 8, cache: true, fit_threads: 1, ..Default::default() });

    // register the file ahead of fitting
    let reg = protocol::request_line(
        1,
        "dataset_from_file",
        vec![("dataset", file_dataset_json(&file))],
    );
    let registered = parse_ok(&srv.handle_line(&reg));
    assert_eq!(registered.field("n").unwrap().as_usize(), Some(prob.n()));
    assert_eq!(registered.field("p").unwrap().as_usize(), Some(prob.p()));
    assert_eq!(registered.field("sparse"), Some(&Json::Bool(true)));

    // fit the file-backed dataset and the identical inline dataset
    let model = |id: u64, dataset: Json| {
        protocol::request_line(
            id,
            "fit_path",
            vec![
                ("dataset", dataset),
                ("q", Json::Num(0.1)),
                ("path_length", Json::Num(8.0)),
            ],
        )
    };
    let from_file = parse_ok(&srv.handle_line(&model(2, file_dataset_json(&file))));
    let from_inline = parse_ok(&srv.handle_line(&model(3, inline_dataset_json(&prob))));

    // Violations and screened/active trajectories agree exactly; the
    // σ-grids agree to cross-storage rounding (dense inline vs sparse
    // file sum in different orders, so this is solver-level, not
    // bitwise — see the module doc).
    assert_eq!(
        from_file.field("total_violations").unwrap().as_f64(),
        from_inline.field("total_violations").unwrap().as_f64()
    );
    assert_eq!(
        from_file.field("steps").unwrap().as_usize(),
        from_inline.field("steps").unwrap().as_usize()
    );
    let na_f = from_file.field("n_active").unwrap().items();
    let na_i = from_inline.field("n_active").unwrap().items();
    assert_eq!(na_f, na_i, "active-set trajectories diverged");
    for (sf, si) in from_file
        .field("sigmas")
        .unwrap()
        .items()
        .iter()
        .zip(from_inline.field("sigmas").unwrap().items())
    {
        let (sf, si) = (sf.as_f64().unwrap(), si.as_f64().unwrap());
        assert!((sf - si).abs() <= 1e-9 * sf.abs(), "sigma grids diverged: {sf} vs {si}");
    }

    // fit_point through the file spec ≡ the same computation in-process
    // on the ingested problem (identical CSC bytes, serial kernels):
    // violations exact, coefficients ≤ 1e-10.
    let point_req = protocol::request_line(
        4,
        "fit_point",
        vec![
            ("dataset", file_dataset_json(&file)),
            ("q", Json::Num(0.1)),
            ("sigma_ratio", Json::Num(0.4)),
            ("screen", Json::Str("strong".to_string())),
        ],
    );
    let served = parse_ok(&srv.handle_line(&point_req));
    let ing = ingest::load_path(&file, &raw(Family::Binomial)).unwrap();
    let mut cfg = PathConfig::new(LambdaKind::Bh { q: 0.1 });
    cfg.length = 50; // ModelSpec's serving default
    let opts = PathOptions::new(cfg)
        .with_strategy(Strategy::StrongSet)
        .with_threads(1);
    let ng = NativeGradient(&ing.problem);
    let seed = zero_seed(&ing.problem, &opts, &ng);
    let local = fit_point(&ing.problem, &opts, &ng, seed.sigma * 0.4, &seed);
    assert_eq!(
        served.field("violations").unwrap().as_usize(),
        Some(local.violations),
        "served violations differ from in-memory"
    );
    assert_eq!(served.field("n_active").unwrap().as_usize(), Some(local.n_active));
    for pair in served.field("nonzeros").unwrap().items() {
        let idx = pair.items()[0].as_usize().unwrap();
        let val = pair.items()[1].as_f64().unwrap();
        assert!(
            (val - local.beta[idx]).abs() <= 1e-10,
            "coef {idx}: served {val} vs local {}",
            local.beta[idx]
        );
    }

    // warm-start cache: an identical re-fit is a cache hit; a sibling
    // model on the same file entry warm-starts (previous-set strategy).
    let again = parse_ok(&srv.handle_line(&model(5, file_dataset_json(&file))));
    assert_eq!(again.field("source").unwrap().as_str(), Some("cache"));
    let sibling = protocol::request_line(
        6,
        "fit_path",
        vec![
            ("dataset", file_dataset_json(&file)),
            ("q", Json::Num(0.1)),
            ("path_length", Json::Num(12.0)),
        ],
    );
    let warm = parse_ok(&srv.handle_line(&sibling));
    assert_eq!(warm.field("source").unwrap().as_str(), Some("fit"));
    assert_eq!(warm.field("strategy").unwrap().as_str(), Some("previous"));

    let _ = std::fs::remove_file(&file);
}

#[test]
fn registry_interns_file_datasets_by_content_and_shares_pack_cache() {
    // Dense file (above the packing density gate) so fits deposit packs.
    let mut rng = Pcg64::new(0xf11e);
    let n = 30;
    let p = 50;
    let mut m = Mat::zeros(n, p);
    for j in 0..p {
        for i in 0..n {
            m.set(i, j, rng.normal());
        }
    }
    let mut y = vec![0.0f64; n];
    m.gemv(
        &(0..p).map(|j| if j < 3 { 1.0 } else { 0.0 }).collect::<Vec<f64>>(),
        &mut y,
    );
    for v in y.iter_mut() {
        *v += 0.1 * rng.normal();
    }
    let prob = Problem::new(Design::Dense(m), y, Family::Gaussian);
    let file_a = tmp("reg-a.csv");
    write_csv(&prob, &file_a).unwrap();
    let file_b = tmp("reg-b.csv");
    std::fs::copy(&file_a, &file_b).unwrap();

    let spec = |p: &Path| DatasetSpec::File {
        path: p.to_str().unwrap().to_string(),
        family: "gaussian".to_string(),
        classes: 3,
        standardize: false,
    };
    let reg = Registry::new(false); // model cache off: every fit runs
    let entry_a = reg.dataset(&spec(&file_a)).unwrap();
    let entry_b = reg.dataset(&spec(&file_b)).unwrap();
    assert!(
        std::sync::Arc::ptr_eq(&entry_a, &entry_b),
        "same bytes at two paths must intern to one entry"
    );

    let build = || {
        let mut cfg = PathConfig::new(LambdaKind::Bh { q: 0.1 });
        cfg.length = 6;
        let opts = PathOptions::new(cfg).with_pack_cache(entry_a.pack_cache());
        let prob = entry_a.problem.as_ref();
        let fit = fit_path(prob, &opts, &NativeGradient(prob));
        let seed = fit.seed();
        let wall = fit.wall_time;
        Ok(CachedModel {
            fit,
            seed,
            strategy: "strong",
            wall_time: wall,
            hits: AtomicU64::new(0),
        })
    };
    assert!(entry_a.pack_cache().is_empty());
    reg.model(&entry_a, "m", build).unwrap();
    assert!(!entry_a.pack_cache().is_empty(), "a fit must deposit packs");
    let (hits_before, _) = entry_a.pack_cache().stats();
    reg.model(&entry_b, "m", build).unwrap();
    let (hits_after, _) = entry_a.pack_cache().stats();
    assert!(
        hits_after > hits_before,
        "a re-fit through the content-interned entry must adopt cached packs \
         ({hits_before} -> {hits_after})"
    );
    let _ = std::fs::remove_file(&file_a);
    let _ = std::fs::remove_file(&file_b);
}

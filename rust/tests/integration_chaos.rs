//! Chaos harness (DESIGN.md §12): every [`FaultPlan`] scenario must end
//! in a typed error or a ladder-degraded *converged* fit. The server
//! never dies, and a clean request after the fault always succeeds.
//!
//! The fault registry is process-global, so every test that arms it (or
//! reads the global resilience counters) serializes on [`CHAOS`].

use std::sync::Mutex;

use slope_screen::fault::{self, FaultPlan};
use slope_screen::jsonio::Json;
use slope_screen::obs::registry as obsreg;
use slope_screen::serve::protocol;
use slope_screen::serve::{Server, ServerConfig};

static CHAOS: Mutex<()> = Mutex::new(());

/// Serialize and recover from a poisoned lock — a failed chaos test must
/// not cascade into every later scenario.
fn chaos_lock() -> std::sync::MutexGuard<'static, ()> {
    CHAOS.lock().unwrap_or_else(|p| p.into_inner())
}

fn server() -> Server {
    Server::new(ServerConfig { threads: 2, queue: 8, cache: true, ..Default::default() })
}

/// A small-but-real path fit: a few dozen FISTA solves, ~tens of ms.
fn fit_line(id: u64, seed: u64) -> String {
    protocol::request_line(
        id,
        "fit_path",
        vec![
            ("dataset", protocol::synth_dataset_json(40, 120, 5, 0.2, "gaussian", seed)),
            ("q", Json::Num(0.1)),
            ("path_length", Json::Num(8.0)),
        ],
    )
}

fn parse(response: &str) -> Json {
    Json::parse(response).unwrap_or_else(|e| panic!("unparseable response {response}: {e}"))
}

fn assert_ok(resp: &Json) {
    assert_eq!(resp.field("ok"), Some(&Json::Bool(true)), "expected success: {resp:?}");
}

fn error_kind(resp: &Json) -> String {
    assert_eq!(resp.field("ok"), Some(&Json::Bool(false)), "expected an error: {resp:?}");
    resp.field("error_kind")
        .and_then(|k| k.as_str())
        .unwrap_or_else(|| panic!("error without error_kind: {resp:?}"))
        .to_string()
}

#[test]
fn planned_panic_is_typed_and_the_server_survives() {
    let _g = chaos_lock();
    fault::clear();
    let srv = server();
    let panics_before = obsreg::SERVE_WORKER_PANICS.get();

    fault::install(FaultPlan { panic_at_solve: Some(1), ..FaultPlan::default() });
    let resp = parse(&srv.handle_line(&fit_line(1, 21)));
    assert_eq!(error_kind(&resp), "panic");
    let msg = resp.field("error").unwrap().as_str().unwrap();
    assert!(msg.contains("planned panic"), "panic payload lost: {msg}");
    assert!(obsreg::SERVE_WORKER_PANICS.get() > panics_before);
    fault::clear();

    // One strike is not a quarantine, and the same server keeps serving
    // the same dataset.
    let clean = parse(&srv.handle_line(&fit_line(2, 21)));
    assert_ok(&clean);
    assert_eq!(clean.field("result").unwrap().field("source").unwrap().as_str(), Some("fit"));
}

#[test]
fn repeated_panics_quarantine_then_reintern_cleanly() {
    let _g = chaos_lock();
    fault::clear();
    let srv = server();
    let quarantined_before = obsreg::REGISTRY_QUARANTINED.get();

    for id in 0..3 {
        // Re-installing resets the solve counter, so each request's first
        // solve panics.
        fault::install(FaultPlan { panic_at_solve: Some(1), ..FaultPlan::default() });
        let resp = parse(&srv.handle_line(&fit_line(id, 33)));
        assert_eq!(error_kind(&resp), "panic", "strike {}", id + 1);
    }
    fault::clear();
    assert_eq!(
        obsreg::REGISTRY_QUARANTINED.get(),
        quarantined_before + 1,
        "three strikes must evict the dataset exactly once"
    );

    // The evicted dataset re-interns from scratch with a clean record.
    let clean = parse(&srv.handle_line(&fit_line(9, 33)));
    assert_ok(&clean);
}

#[test]
fn slow_solve_against_a_deadline_is_a_typed_deadline_error() {
    let _g = chaos_lock();
    fault::clear();
    let srv = server();
    let expired_before = obsreg::SERVE_DEADLINE_EXPIRED.get();

    fault::install(FaultPlan { slow_solve_ms: 60, seed: 7, ..FaultPlan::default() });
    let line = protocol::request_line(
        1,
        "fit_path",
        vec![
            ("dataset", protocol::synth_dataset_json(40, 120, 5, 0.2, "gaussian", 44)),
            ("q", Json::Num(0.1)),
            ("path_length", Json::Num(8.0)),
            ("deadline_ms", Json::Num(20.0)),
        ],
    );
    let resp = parse(&srv.handle_line(&line));
    assert_eq!(error_kind(&resp), "deadline");
    let msg = resp.field("error").unwrap().as_str().unwrap();
    assert!(msg.contains("deadline"), "{msg}");
    // Partial progress rides along in the error, never in the cache.
    let partial = resp.field("partial").expect("deadline errors carry partial progress");
    assert!(partial.field("steps_done").unwrap().as_usize().is_some());
    assert!(obsreg::SERVE_DEADLINE_EXPIRED.get() > expired_before);
    fault::clear();

    // The same model without a deadline must be a full fresh fit — an
    // expired request must not have cached a partial result.
    let clean = parse(&srv.handle_line(&fit_line(2, 44)));
    assert_ok(&clean);
    let result = clean.field("result").unwrap();
    assert_eq!(result.field("source").unwrap().as_str(), Some("fit"));
    assert!(result.field("steps").unwrap().as_usize().unwrap() >= 2);
}

/// Cross-request batching (DESIGN.md §14): a panic inside a batched
/// solve must fail *that batch* — every coalesced member gets the typed
/// `panic` error — and nothing else. The server survives, two strikes
/// do not quarantine the dataset, and a clean follow-up point fit on
/// the same dataset succeeds.
#[test]
fn panic_in_a_batched_solve_fails_every_member_typed_and_server_survives() {
    let _g = chaos_lock();
    fault::clear();
    let srv = Server::new(ServerConfig {
        threads: 2,
        queue: 8,
        cache: true,
        gather_window_ms: 500,
        max_batch: 2,
        ..Default::default()
    });
    let point_line = |id: u64, ratio: f64| {
        protocol::request_line(
            id,
            "fit_point",
            vec![
                ("dataset", protocol::synth_dataset_json(30, 80, 4, 0.1, "gaussian", 77)),
                ("q", Json::Num(0.1)),
                ("sigma_ratio", Json::Num(ratio)),
            ],
        )
    };
    let panics_before = obsreg::SERVE_WORKER_PANICS.get();
    // Interning the dataset and computing σ_max run no FISTA solves, so
    // the armed panic fires inside the coalesced batch job itself.
    fault::install(FaultPlan { panic_at_solve: Some(1), ..FaultPlan::default() });
    let barrier = std::sync::Barrier::new(2);
    let (first, second) = std::thread::scope(|s| {
        let a = s.spawn(|| {
            barrier.wait();
            srv.handle_line(&point_line(1, 0.5))
        });
        let b = s.spawn(|| {
            barrier.wait();
            srv.handle_line(&point_line(2, 0.35))
        });
        (a.join().unwrap(), b.join().unwrap())
    });
    fault::clear();

    for resp in [parse(&first), parse(&second)] {
        assert_eq!(error_kind(&resp), "panic", "every batch member fails typed: {resp:?}");
        let msg = resp.field("error").unwrap().as_str().unwrap();
        assert!(msg.contains("planned panic"), "panic payload lost: {msg}");
    }
    assert!(obsreg::SERVE_WORKER_PANICS.get() > panics_before);

    // Two strikes (one per member) are not a quarantine: the same
    // server keeps serving the same dataset.
    let clean = parse(&srv.handle_line(&point_line(3, 0.5)));
    assert_ok(&clean);
    assert_eq!(
        clean.field("result").unwrap().field("solver_converged"),
        Some(&Json::Bool(true))
    );
}

#[test]
fn nan_gradient_degrades_to_a_converged_fit() {
    let _g = chaos_lock();
    fault::clear();
    let srv = server();
    let degraded_before = obsreg::PATH_DEGRADED_STEPS.get();

    fault::install(FaultPlan { nan_grad_at_solve: Some(1), ..FaultPlan::default() });
    let resp = parse(&srv.handle_line(&fit_line(1, 55)));
    fault::clear();

    // A poisoned gradient is not an error: the degradation ladder retries
    // the step under a more conservative strategy and reports a
    // *converged* fit with the rescue on the record.
    assert_ok(&resp);
    let result = resp.field("result").unwrap();
    assert_eq!(result.field("solver_converged"), Some(&Json::Bool(true)));
    assert!(
        result.field("degraded_steps").unwrap().as_usize().unwrap() >= 1,
        "the rescue must be visible in the response: {result:?}"
    );
    assert!(obsreg::PATH_DEGRADED_STEPS.get() > degraded_before);
}

#[test]
fn disarmed_plans_are_bitwise_invisible() {
    use slope_screen::data::synth::{BetaSpec, DesignKind, SyntheticSpec};
    use slope_screen::rng::Pcg64;
    use slope_screen::slope::family::Family;
    use slope_screen::slope::lambda::{LambdaKind, PathConfig};
    use slope_screen::slope::path::{fit_path, NativeGradient, PathOptions};

    let _g = chaos_lock();
    fault::clear();
    let prob = SyntheticSpec {
        n: 40,
        p: 80,
        rho: 0.2,
        design: DesignKind::Compound,
        beta: BetaSpec::PlusMinus { k: 5, scale: 2.0 },
        family: Family::Gaussian,
        noise_sd: 1.0,
        standardize: true,
    }
    .generate(&mut Pcg64::new(3));
    let mut cfg = PathConfig::new(LambdaKind::Bh { q: 0.1 });
    cfg.length = 6;
    let opts = PathOptions::new(cfg);
    let bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<u64>>();

    let baseline = fit_path(&prob, &opts, &NativeGradient(&prob));

    // An armed-but-empty plan must not perturb a single bit...
    fault::install(FaultPlan::default());
    let armed_empty = fit_path(&prob, &opts, &NativeGradient(&prob));
    // ...and neither must the disarmed registry after a clear.
    fault::clear();
    let cleared = fit_path(&prob, &opts, &NativeGradient(&prob));

    for (label, fit) in [("armed-empty", &armed_empty), ("cleared", &cleared)] {
        assert_eq!(fit.sigmas.len(), baseline.sigmas.len(), "{label}");
        assert_eq!(bits(&fit.final_beta), bits(&baseline.final_beta), "{label}: beta drifted");
        assert_eq!(bits(&fit.final_grad), bits(&baseline.final_grad), "{label}: grad drifted");
        assert_eq!(bits(&fit.sigmas), bits(&baseline.sigmas), "{label}: grid drifted");
    }
}

mod checkpointing {
    use super::*;
    use slope_screen::data::synth::{BetaSpec, DesignKind, SyntheticSpec};
    use slope_screen::rng::Pcg64;
    use slope_screen::slope::checkpoint::CheckpointError;
    use slope_screen::slope::family::{Family, Problem};
    use slope_screen::slope::lambda::{LambdaKind, PathConfig};
    use slope_screen::slope::path::{
        fit_path, fit_path_checkpointed, resume_path, CheckpointConfig, NativeGradient,
        PathOptions, Strategy,
    };

    fn problem(seed: u64) -> Problem {
        SyntheticSpec {
            n: 40,
            p: 120,
            rho: 0.2,
            design: DesignKind::Compound,
            beta: BetaSpec::PlusMinus { k: 5, scale: 2.0 },
            family: Family::Gaussian,
            noise_sd: 1.0,
            standardize: true,
        }
        .generate(&mut Pcg64::new(seed))
    }

    /// Early stopping off: the kill sweep below must visit *every* σ-step
    /// boundary, and a data-dependent stop would hide the tail.
    fn options(strategy: Strategy, threads: usize) -> PathOptions {
        let mut cfg = PathConfig::new(LambdaKind::Bh { q: 0.1 });
        cfg.length = 8;
        cfg = cfg.without_early_stopping();
        PathOptions::new(cfg).with_strategy(strategy).with_threads(threads)
    }

    fn ckpt(tag: &str) -> CheckpointConfig {
        CheckpointConfig {
            path: std::env::temp_dir()
                .join(format!("slope-chaos-ckpt-{tag}-{}.bin", std::process::id())),
            every: 1,
            dataset_fingerprint: 0xDA7A_F00D,
        }
    }

    fn scrub(cfg: &CheckpointConfig) {
        for suffix in ["", ".prev", ".tmp"] {
            let mut p = cfg.path.clone().into_os_string();
            p.push(suffix);
            let _ = std::fs::remove_file(std::path::PathBuf::from(p));
        }
    }

    fn bits(v: &[f64]) -> Vec<u64> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    /// The resume contract (ISSUE acceptance): killing the process at ANY
    /// σ-step boundary and resuming must reproduce the uninterrupted fit
    /// bit for bit — across thread counts and screening strategies.
    #[test]
    fn resumed_fit_matches_uninterrupted_bitwise() {
        let _g = chaos_lock();
        fault::clear();
        for strategy in [Strategy::StrongSet, Strategy::GapHybrid] {
            for threads in [1usize, 2, 7] {
                let prob = problem(77);
                let opts = options(strategy, threads);
                let baseline = fit_path(&prob, &opts, &NativeGradient(&prob));
                let n_steps = baseline.sigmas.len();
                assert!(n_steps >= 4, "path too short to exercise the kill sweep");
                for kill_at in 1..n_steps as u64 {
                    let cfg = ckpt("bitwise");
                    scrub(&cfg);
                    fault::install(FaultPlan {
                        kill_after_step: Some(kill_at),
                        ..FaultPlan::default()
                    });
                    let killed = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                        fit_path_checkpointed(&prob, &opts, &NativeGradient(&prob), None, &cfg)
                    }));
                    fault::clear();
                    assert!(
                        killed.is_err(),
                        "{} t{threads}: the planned kill at step {kill_at} must fire",
                        strategy.name()
                    );
                    let (resumed, start) =
                        resume_path(&prob, &opts, &NativeGradient(&prob), &cfg)
                            .unwrap_or_else(|e| {
                                panic!(
                                    "{} t{threads} kill@{kill_at}: resume failed: {e}",
                                    strategy.name()
                                )
                            });
                    let label =
                        format!("{} t{threads} kill@{kill_at}", strategy.name());
                    assert_eq!(start as u64, kill_at + 1, "{label}: wrong resume step");
                    assert_eq!(resumed.sigmas.len(), n_steps, "{label}: step count");
                    assert_eq!(
                        bits(&resumed.final_beta),
                        bits(&baseline.final_beta),
                        "{label}: final_beta drifted"
                    );
                    assert_eq!(
                        bits(&resumed.final_grad),
                        bits(&baseline.final_grad),
                        "{label}: final_grad drifted"
                    );
                    assert_eq!(
                        resumed.total_violations, baseline.total_violations,
                        "{label}: violation count drifted"
                    );
                    scrub(&cfg);
                }
            }
        }
    }

    /// A snapshot torn mid-write (the `truncate_checkpoint` fault halves
    /// the freshly-landed file) must be detected, counted, and recovered
    /// from via the rotated `.prev` snapshot — still bitwise identical.
    #[test]
    fn truncated_snapshot_falls_back_to_the_previous_good_one() {
        let _g = chaos_lock();
        fault::clear();
        let prob = problem(88);
        let opts = options(Strategy::StrongSet, 2);
        let baseline = fit_path(&prob, &opts, &NativeGradient(&prob));
        let cfg = ckpt("truncate");
        scrub(&cfg);
        // Truncate the 3rd snapshot the moment it lands, then kill: disk
        // now holds a torn primary and an intact step-2 `.prev`.
        fault::install(FaultPlan {
            truncate_checkpoint: Some(3),
            kill_after_step: Some(3),
            ..FaultPlan::default()
        });
        let killed = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            fit_path_checkpointed(&prob, &opts, &NativeGradient(&prob), None, &cfg)
        }));
        fault::clear();
        assert!(killed.is_err(), "the planned kill must fire");
        let skips_before = obsreg::CKPT_CORRUPT_SKIPS.get();
        let (resumed, start) = resume_path(&prob, &opts, &NativeGradient(&prob), &cfg)
            .expect("the .prev snapshot must rescue the resume");
        assert!(
            obsreg::CKPT_CORRUPT_SKIPS.get() > skips_before,
            "the torn primary must be counted as a corrupt skip"
        );
        assert_eq!(start, 3, "fallback resumes from the step-2 snapshot");
        assert_eq!(bits(&resumed.final_beta), bits(&baseline.final_beta));
        assert_eq!(bits(&resumed.final_grad), bits(&baseline.final_grad));
        scrub(&cfg);
    }

    /// A checkpoint of dataset A must refuse to resume a fit of dataset
    /// B with a typed mismatch — never by silently continuing.
    #[test]
    fn resume_against_the_wrong_dataset_is_a_typed_mismatch() {
        let _g = chaos_lock();
        fault::clear();
        let prob = problem(99);
        let opts = options(Strategy::StrongSet, 1);
        let cfg = ckpt("mismatch");
        scrub(&cfg);
        fit_path_checkpointed(&prob, &opts, &NativeGradient(&prob), None, &cfg);
        let wrong =
            CheckpointConfig { dataset_fingerprint: cfg.dataset_fingerprint ^ 1, ..cfg.clone() };
        match resume_path(&prob, &opts, &NativeGradient(&prob), &wrong) {
            Err(e @ CheckpointError::DatasetMismatch { .. }) => {
                assert_eq!(e.kind(), "dataset_mismatch");
            }
            other => panic!("expected a dataset mismatch, got {other:?}"),
        }
        scrub(&cfg);
    }
}

/// Replication failover chaos (DESIGN.md §15): kill -9 the primary and
/// the promoted standby answers warm and bitwise-identical; a deposed
/// primary is fenced; corrupted replication frames are skipped, never
/// applied.
#[cfg(unix)]
mod replication {
    use super::*;
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::Arc;
    use std::time::{Duration, Instant};

    use slope_screen::serve::client::{connect_tcp_with_retry, Client};
    use slope_screen::serve::{net, replica};

    fn state_dir(tag: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("slope-repl-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn cfg_with(state: &std::path::Path, standby: bool) -> ServerConfig {
        ServerConfig {
            threads: 2,
            queue: 8,
            cache: true,
            standby,
            state_dir: Some(state.to_path_buf()),
            ..Default::default()
        }
    }

    /// Bind a TCP transport on a kernel-chosen port and run it on its
    /// own thread. The abort flag is the kill switch: flipping it makes
    /// the poll loop return on its next tick with no drain and no
    /// goodbye — as close to `kill -9` as one process can get.
    fn spawn_tcp(
        server: &Arc<Server>,
    ) -> (String, Arc<AtomicBool>, std::thread::JoinHandle<std::io::Result<()>>) {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let abort = Arc::new(AtomicBool::new(false));
        let srv = Arc::clone(server);
        let flag = Arc::clone(&abort);
        let handle =
            std::thread::spawn(move || net::serve_tcp_listener_abortable(&srv, listener, &flag));
        (addr, abort, handle)
    }

    fn connect(addr: &str) -> Client {
        connect_tcp_with_retry(addr, 80, 25).expect("serve TCP endpoint")
    }

    fn wait_for(what: &str, mut cond: impl FnMut() -> bool) {
        let deadline = Instant::now() + Duration::from_secs(20);
        while !cond() {
            assert!(Instant::now() < deadline, "timed out waiting for {what}");
            std::thread::sleep(Duration::from_millis(10));
        }
    }

    /// The model key of the (single-fingerprint) restored seed a server
    /// holds, via the same snapshot stream compaction writes.
    fn seed_key(server: &Server) -> Option<String> {
        server.registry().snapshot_records().iter().find_map(|r| {
            if r.field("kind").and_then(Json::as_str) == Some("model") {
                Some(r.field("key").and_then(Json::as_str).unwrap_or("").to_string())
            } else {
                None
            }
        })
    }

    fn point_line(id: u64, seed: u64) -> String {
        protocol::request_line(
            id,
            "fit_point",
            vec![
                ("dataset", protocol::synth_dataset_json(40, 120, 5, 0.2, "gaussian", seed)),
                ("q", Json::Num(0.1)),
                ("sigma_ratio", Json::Num(0.4)),
            ],
        )
    }

    /// The tentpole acceptance test: fit on the primary, kill it with no
    /// drain, promote the standby, and the *same* `fit_point` through
    /// the client's endpoint rotation must come back warm and
    /// bitwise-identical (wall time aside) — the replicated journal kept
    /// the standby's seed cache hot.
    #[test]
    fn primary_death_fails_over_to_warm_standby_bitwise() {
        let _g = chaos_lock();
        fault::clear();
        let dir_a = state_dir("primary-a");
        let dir_b = state_dir("standby-a");
        let primary = Arc::new(Server::new(cfg_with(&dir_a, false)));
        let (paddr, pabort, phandle) = spawn_tcp(&primary);
        let standby = Arc::new(Server::new(cfg_with(&dir_b, true)));
        let (saddr, sabort, shandle) = spawn_tcp(&standby);
        let repl = replica::spawn_standby(
            Arc::clone(&standby),
            replica::StandbyConfig {
                primaries: vec![paddr.clone()],
                heartbeat_timeout_ms: 250,
                ..Default::default()
            },
        );

        let mut client = connect(&paddr);
        let fit = parse(&client.round_trip(&fit_line(1, 321)).unwrap());
        assert_ok(&fit);
        let reference = parse(&client.round_trip(&point_line(2, 321)).unwrap());
        assert_ok(&reference);
        let rref = reference.field("result").unwrap();
        assert_eq!(
            rref.field("warm"),
            Some(&Json::Bool(true)),
            "the primary itself warms from the journaled path seed"
        );

        // The journal ships asynchronously; wait until the standby holds
        // the replicated seed before pulling the plug.
        wait_for("the seed to replicate", || seed_key(&standby).is_some());

        // kill -9: the primary's transport vanishes mid-heartbeat.
        pabort.store(true, Ordering::SeqCst);
        phandle.join().unwrap().unwrap();

        let mut sclient = connect(&saddr);
        let promoted = parse(
            &sclient.round_trip(&protocol::request_line(3, "promote", vec![])).unwrap(),
        );
        assert_ok(&promoted);
        let pr = promoted.field("result").unwrap();
        assert_eq!(pr.field("promoted"), Some(&Json::Bool(true)));
        assert_eq!(pr.field("epoch").and_then(Json::as_usize), Some(1));

        // A fresh client lists the dead primary first: the connect must
        // rotate past it, and the failed-over fit must be the bitwise
        // answer the primary would have given.
        let mut failover = Client::connect_tcp(&format!("{paddr},{saddr}")).unwrap();
        let fo = parse(&failover.round_trip(&point_line(4, 321)).unwrap());
        assert_ok(&fo);
        let rfo = fo.field("result").unwrap();
        assert_eq!(rfo.field("warm"), Some(&Json::Bool(true)), "standby seed cache was cold");
        let bits = |r: &Json, f: &str| {
            r.field(f)
                .and_then(Json::as_f64)
                .unwrap_or_else(|| panic!("missing field {f}"))
                .to_bits()
        };
        for f in ["sigma", "sigma_max", "deviance", "dev_ratio"] {
            assert_eq!(bits(rfo, f), bits(rref, f), "{f} drifted across failover");
        }
        assert_eq!(rfo.field("nonzeros"), rref.field("nonzeros"), "support drifted");

        let health = parse(
            &failover.round_trip(&protocol::request_line(5, "health", vec![])).unwrap(),
        );
        assert_ok(&health);
        let h = health.field("result").unwrap();
        assert_eq!(h.field("role").and_then(Json::as_str), Some("primary"));
        assert_eq!(h.field("epoch").and_then(Json::as_usize), Some(1));
        assert_eq!(h.field("state").and_then(Json::as_str), Some("ready"));

        sabort.store(true, Ordering::SeqCst);
        shandle.join().unwrap().unwrap();
        repl.join().unwrap();
        let _ = std::fs::remove_dir_all(&dir_a);
        let _ = std::fs::remove_dir_all(&dir_b);
    }

    /// Epoch fencing: once any node has been promoted past it, the old
    /// primary must refuse writes — split-brain protection. The deposed
    /// node still answers health (degraded) and stats.
    #[test]
    fn stale_epoch_ex_primary_is_fenced() {
        use std::io::{BufRead, BufReader, Write};

        let _g = chaos_lock();
        fault::clear();
        let dir = state_dir("fence");
        let primary = Arc::new(Server::new(cfg_with(&dir, false)));
        let (addr, abort, handle) = spawn_tcp(&primary);

        // A standby promoted elsewhere (epoch 5) announces itself; the
        // subscription is refused *and* the refusal deposes this node.
        let mut raw = std::net::TcpStream::connect(&addr).unwrap();
        raw.write_all(b"{\"id\": 1, \"op\": \"repl_subscribe\", \"epoch\": 5}\n").unwrap();
        let mut line = String::new();
        BufReader::new(raw.try_clone().unwrap()).read_line(&mut line).unwrap();
        let refusal = parse(&line);
        assert_eq!(error_kind(&refusal), "fenced");

        let mut client = connect(&addr);
        let refused = parse(&client.round_trip(&fit_line(2, 77)).unwrap());
        assert_eq!(error_kind(&refused), "fenced");
        assert!(
            refused.field("error").unwrap().as_str().unwrap().contains("epoch 5"),
            "{refused:?}"
        );

        let health = parse(
            &client.round_trip(&protocol::request_line(3, "health", vec![])).unwrap(),
        );
        assert_ok(&health);
        let h = health.field("result").unwrap();
        assert_eq!(h.field("role").and_then(Json::as_str), Some("fenced"));
        assert_eq!(h.field("epoch").and_then(Json::as_usize), Some(5));
        assert_eq!(h.field("state").and_then(Json::as_str), Some("degraded"));
        // Reads survive the fence.
        assert_ok(&parse(&client.round_trip(&protocol::request_line(4, "stats", vec![])).unwrap()));

        abort.store(true, Ordering::SeqCst);
        handle.join().unwrap().unwrap();
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// A replication frame corrupted in flight (armed digest flip) must
    /// be skipped and counted on the standby — never applied — and the
    /// next clean record for the same fingerprint heals the gap.
    #[test]
    fn corrupt_replication_frames_are_skipped_never_applied() {
        let _g = chaos_lock();
        fault::clear();
        let dir_p = state_dir("flip-p");
        let dir_s = state_dir("flip-s");
        let primary = Arc::new(Server::new(cfg_with(&dir_p, false)));
        let (paddr, pabort, phandle) = spawn_tcp(&primary);
        let standby = Arc::new(Server::new(cfg_with(&dir_s, true)));
        let repl = replica::spawn_standby(
            Arc::clone(&standby),
            replica::StandbyConfig {
                primaries: vec![paddr.clone()],
                heartbeat_timeout_ms: 250,
                ..Default::default()
            },
        );
        let mut client = connect(&paddr);
        // Three path fits on one dataset, distinct model keys; the
        // restored seed is last-record-wins per fingerprint, so the
        // standby's seed key tells exactly which record it applied last.
        let fit_q = |id: u64, q: f64| {
            protocol::request_line(
                id,
                "fit_path",
                vec![
                    ("dataset", protocol::synth_dataset_json(40, 120, 5, 0.2, "gaussian", 555)),
                    ("q", Json::Num(q)),
                    ("path_length", Json::Num(6.0)),
                ],
            )
        };
        assert_ok(&parse(&client.round_trip(&fit_q(1, 0.1)).unwrap()));
        let key1 = seed_key(&primary).expect("the primary journaled its seed");
        wait_for("the first seed to replicate", || seed_key(&standby).as_ref() == Some(&key1));

        // Arm the wire fault: the next shipped record's digest is
        // flipped in flight.
        let skips_before = obsreg::REPL_DIGEST_SKIPS.get();
        fault::install(FaultPlan { repl_flip_digest_at: Some(1), ..FaultPlan::default() });
        assert_ok(&parse(&client.round_trip(&fit_q(2, 0.2)).unwrap()));
        let key2 = seed_key(&primary).expect("second seed journaled");
        assert_ne!(key2, key1, "distinct model keys are the point of this test");
        wait_for("the flipped frame to be counted", || {
            obsreg::REPL_DIGEST_SKIPS.get() > skips_before
        });
        fault::clear();
        assert_eq!(
            seed_key(&standby).as_ref(),
            Some(&key1),
            "a record with a bad digest must never be applied"
        );

        // A clean later record heals the standby.
        assert_ok(&parse(&client.round_trip(&fit_q(3, 0.05)).unwrap()));
        let key3 = seed_key(&primary).expect("third seed journaled");
        wait_for("the clean seed to replicate", || seed_key(&standby).as_ref() == Some(&key3));

        // Shut the standby's loop down before the primary vanishes.
        assert_ok(&parse(&standby.handle_line("{\"id\": 9, \"op\": \"shutdown\"}")));
        repl.join().unwrap();
        pabort.store(true, Ordering::SeqCst);
        phandle.join().unwrap().unwrap();
        let _ = std::fs::remove_dir_all(&dir_p);
        let _ = std::fs::remove_dir_all(&dir_s);
    }
}

#[cfg(unix)]
mod socket {
    use super::*;
    use std::sync::Arc;
    use std::time::Duration;

    use slope_screen::serve::client::{connect_with_retry, Client};

    fn socket_path(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("slope-chaos-{}-{name}.sock", std::process::id()))
    }

    fn spawn_server(
        cfg: ServerConfig,
        path: &std::path::Path,
    ) -> (Arc<Server>, std::thread::JoinHandle<std::io::Result<()>>) {
        let server = Arc::new(Server::new(cfg));
        let srv = Arc::clone(&server);
        let sock = path.to_path_buf();
        let handle = std::thread::spawn(move || srv.serve_unix(&sock));
        (server, handle)
    }

    /// Join the server thread under a watchdog — a drain that hangs must
    /// fail the test, not wedge the suite.
    fn join_within(handle: std::thread::JoinHandle<std::io::Result<()>>, secs: u64, what: &str) {
        let (tx, rx) = std::sync::mpsc::channel();
        std::thread::spawn(move || {
            let _ = tx.send(handle.join());
        });
        match rx.recv_timeout(Duration::from_secs(secs)) {
            Ok(joined) => {
                joined.expect(what).unwrap_or_else(|e| panic!("{what}: transport error {e}"));
            }
            Err(_) => panic!("{what}: server thread did not join within {secs}s"),
        }
    }

    fn connect(path: &std::path::Path) -> Client {
        connect_with_retry(path, 80, 25).expect("serve socket")
    }

    #[test]
    fn connection_drop_mid_stream_then_clean_reconnect() {
        let _g = chaos_lock();
        fault::clear();
        let sock = socket_path("drop");
        let (_server, handle) =
            spawn_server(ServerConfig { threads: 2, ..Default::default() }, &sock);

        // Arm before connecting: the per-connection trigger is read when
        // the handler starts.
        fault::install(FaultPlan { drop_after_lines: Some(1), ..FaultPlan::default() });
        let mut client = connect(&sock);
        let stats = protocol::request_line(1, "stats", vec![]);
        let first = client.round_trip(&stats).expect("line 1 is served before the drop");
        assert_ok(&parse(&first));
        // The second line on the same connection is severed mid-stream.
        let second = client.round_trip(&stats);
        assert!(second.is_err(), "expected a dropped connection, got {second:?}");
        fault::clear();

        // The server itself is healthy: reconnect and keep working.
        client.reconnect().expect("reconnect after the drop");
        let again = client.round_trip(&stats).expect("clean request after reconnect");
        assert_ok(&parse(&again));

        let _ = client.round_trip(&protocol::request_line(9, "shutdown", vec![]));
        join_within(handle, 30, "drop scenario shutdown");
    }

    #[test]
    fn shutdown_while_busy_drains_exactly_once() {
        let _g = chaos_lock();
        fault::clear();
        let sock = socket_path("drain");
        let (_server, handle) = spawn_server(
            ServerConfig { threads: 2, cache: false, ..Default::default() },
            &sock,
        );

        // Slow every solve so the fit is reliably still in flight when
        // the shutdown lands.
        fault::install(FaultPlan { slow_solve_ms: 30, seed: 11, ..FaultPlan::default() });
        let sock_a = sock.clone();
        let busy = std::thread::spawn(move || {
            let mut a = connect(&sock_a);
            let line = protocol::request_line(
                1,
                "fit_path",
                vec![
                    ("dataset", protocol::synth_dataset_json(40, 120, 5, 0.2, "gaussian", 66)),
                    ("q", Json::Num(0.1)),
                    ("path_length", Json::Num(12.0)),
                ],
            );
            let first = a.round_trip(&line);
            // After the drain the connection must be closed: no second
            // response ever arrives.
            let after = a.round_trip(&protocol::request_line(2, "stats", vec![]));
            (first, after)
        });

        std::thread::sleep(Duration::from_millis(100));
        let mut b = connect(&sock);
        let bye = b.round_trip(&protocol::request_line(9, "shutdown", vec![])).unwrap();
        assert_ok(&parse(&bye));
        join_within(handle, 30, "busy drain");
        fault::clear();

        let (first, after) = busy.join().unwrap();
        // Exactly one response for the accepted request: either the
        // completed fit (admitted before the drain) or a typed shutdown
        // rejection (still queued when the drain began) — never silence,
        // never two answers.
        let first = first.expect("the in-flight request gets exactly one response");
        let resp = parse(&first);
        if resp.field("ok") == Some(&Json::Bool(true)) {
            let result = resp.field("result").unwrap();
            assert_eq!(result.field("solver_converged"), Some(&Json::Bool(true)));
        } else {
            assert_eq!(error_kind(&resp), "shutdown");
        }
        assert!(after.is_err(), "no responses after the drain, got {after:?}");
    }

    /// The drain handshake regression (ISSUE satellite): shutdown must
    /// wait for every busy handler to *flush its response*, not for a
    /// fixed grace period. Slow every solve well past the old 50 ms
    /// sleep — the in-flight fit's complete response still arrives
    /// before the transport is severed.
    #[test]
    fn drain_flushes_the_inflight_response_under_slow_solves() {
        let _g = chaos_lock();
        fault::clear();
        let sock = socket_path("slowdrain");
        let (_server, handle) = spawn_server(
            ServerConfig { threads: 2, cache: false, ..Default::default() },
            &sock,
        );

        fault::install(FaultPlan { slow_solve_ms: 150, seed: 13, ..FaultPlan::default() });
        let sock_a = sock.clone();
        let busy = std::thread::spawn(move || {
            let mut a = connect(&sock_a);
            let line = protocol::request_line(
                1,
                "fit_path",
                vec![
                    ("dataset", protocol::synth_dataset_json(40, 120, 5, 0.2, "gaussian", 91)),
                    ("q", Json::Num(0.1)),
                    ("path_length", Json::Num(6.0)),
                ],
            );
            a.round_trip(&line)
        });
        // The fit is admitted and mid-solve (each solve sleeps ≥150 ms)
        // when the shutdown lands on a second connection.
        std::thread::sleep(Duration::from_millis(250));
        let mut b = connect(&sock);
        let bye = b.round_trip(&protocol::request_line(9, "shutdown", vec![])).unwrap();
        assert_ok(&parse(&bye));
        join_within(handle, 30, "slow-solve drain");
        fault::clear();

        // The handshake held the socket open until the handler flushed:
        // a complete, parseable fit response — never a torn line, never
        // a bare hangup.
        let first =
            busy.join().unwrap().expect("response must be flushed before the drain severs");
        let resp = parse(&first);
        assert_ok(&resp);
        assert_eq!(
            resp.field("result").unwrap().field("solver_converged"),
            Some(&Json::Bool(true))
        );
    }

    #[test]
    fn oversized_line_over_the_socket_is_survivable() {
        let _g = chaos_lock();
        fault::clear();
        let sock = socket_path("oversize");
        let (_server, handle) = spawn_server(
            ServerConfig { max_line_bytes: 2048, ..Default::default() },
            &sock,
        );

        let mut client = connect(&sock);
        let huge = format!(r#"{{"id":1,"op":"stats","pad":"{}"}}"#, "x".repeat(4096));
        let resp = parse(&client.round_trip(&huge).expect("typed error, not a hangup"));
        assert_eq!(error_kind(&resp), "oversized_line");
        assert!(resp.field("error").unwrap().as_str().unwrap().contains("2048"));

        // The connection survives the oversized line.
        let ok = client.round_trip(&protocol::request_line(2, "stats", vec![])).unwrap();
        assert_ok(&parse(&ok));

        let _ = client.round_trip(&protocol::request_line(9, "shutdown", vec![]));
        join_within(handle, 30, "oversize scenario shutdown");
    }
}

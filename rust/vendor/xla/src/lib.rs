//! Offline stub of the `xla` PJRT bindings.
//!
//! The real crate (xla_extension) links the PJRT C API and is not present
//! in this build environment. This stub keeps the `runtime` layer
//! compiling with the identical call surface; every entry point returns an
//! "unavailable" error, so `Engine::cpu()` fails cleanly and callers fall
//! back to the native gradient engine (`cmd_info` prints the reason, the
//! integration tests skip). Swapping the real crate back in requires no
//! source change — only this path dependency.

use std::fmt;

/// Error raised by every stub entry point.
pub struct Error {
    msg: String,
}

impl Error {
    fn unavailable(what: &str) -> Error {
        Error { msg: format!("{what}: xla/PJRT runtime not available in this build (vendor/xla stub)") }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

/// Stub result type.
pub type Result<T> = std::result::Result<T, Error>;

/// Stub PJRT client. [`PjRtClient::cpu`] always fails.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    /// Create the CPU client — always unavailable in the stub.
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::unavailable("PjRtClient::cpu"))
    }

    /// Platform string of the client.
    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    /// Compile a computation for this client.
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable("PjRtClient::compile"))
    }

    /// Upload a host buffer to the device.
    pub fn buffer_from_host_buffer<T: Copy>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        Err(Error::unavailable("PjRtClient::buffer_from_host_buffer"))
    }
}

/// Stub HLO module proto.
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    /// Parse an HLO text file — always unavailable in the stub.
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::unavailable("HloModuleProto::from_text_file"))
    }
}

/// Stub XLA computation handle.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    /// Wrap a parsed proto.
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// Stub device buffer.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    /// Fetch the buffer back to the host as a literal.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Stub loaded executable.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    /// Execute with borrowed argument buffers.
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable("PjRtLoadedExecutable::execute_b"))
    }
}

/// Stub literal (host-side tensor value).
pub struct Literal {
    _private: (),
}

impl Literal {
    /// Unwrap a 1-tuple literal.
    pub fn to_tuple1(&self) -> Result<Literal> {
        Err(Error::unavailable("Literal::to_tuple1"))
    }

    /// Convert to a flat host vector.
    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(Error::unavailable("Literal::to_vec"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_client_reports_unavailable() {
        let err = PjRtClient::cpu().err().expect("stub must fail");
        assert!(err.to_string().contains("not available"));
    }
}

//! Offline stand-in for the `anyhow` crate.
//!
//! The build environment has no network access, so this vendored shim
//! provides the (small) subset of the `anyhow` API this repository uses:
//! [`Error`], [`Result`], the [`Context`] extension trait for `Result` and
//! `Option`, and the [`anyhow!`] macro. Error values are a message string
//! plus the chain of context strings, rendered as `context: cause`.

use std::fmt;

/// A string-backed error with context chaining.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string() }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error { msg: format!("{context}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

/// `anyhow::Result` with the usual defaulted error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Context extension for `Result` and `Option`, mirroring `anyhow::Context`.
pub trait Context<T> {
    /// Attach a context message to the error case.
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;

    /// Attach a lazily-evaluated context message to the error case.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error { msg: format!("{context}: {e}") })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| Error { msg: format!("{}: {e}", f()) })
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error { msg: context.to_string() })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error { msg: f().to_string() })
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn context_chains_messages() {
        let base: Result<(), std::io::Error> =
            Err(std::io::Error::new(std::io::ErrorKind::NotFound, "gone"));
        let err = base.context("reading file").unwrap_err();
        assert_eq!(err.to_string(), "reading file: gone");
    }

    #[test]
    fn option_context() {
        let none: Option<u32> = None;
        assert_eq!(none.context("missing").unwrap_err().to_string(), "missing");
        assert_eq!(Some(3u32).context("missing").unwrap(), 3);
    }

    #[test]
    fn macro_formats() {
        let e = anyhow!("bad value {} in {}", 7, "field");
        assert_eq!(e.to_string(), "bad value 7 in field");
    }
}

//! Quickstart: fit a SLOPE regularization path with the strong screening
//! rule, exercising all three layers of the stack:
//!
//! * Layer 1/2 — the AOT-compiled JAX/Pallas gradient artifact, loaded and
//!   executed through PJRT (no Python at run time),
//! * Layer 3 — the Rust path driver with Algorithm 3 (strong set) and the
//!   KKT safeguard.
//!
//! Run: `cargo run --release --example quickstart`
//! (requires `make artifacts` once beforehand).

use slope_screen::data::synth::{BetaSpec, DesignKind, SyntheticSpec};
use slope_screen::rng::Pcg64;
use slope_screen::runtime::{default_artifact_dir, ArtifactGradient, Manifest};
use slope_screen::slope::family::Family;
use slope_screen::slope::lambda::{LambdaKind, PathConfig};
use slope_screen::slope::path::{fit_path, FullGradient, NativeGradient, PathOptions};

fn main() -> anyhow::Result<()> {
    // A small p > n problem with correlated predictors.
    let spec = SyntheticSpec {
        n: 100,
        p: 400,
        rho: 0.3,
        design: DesignKind::Compound,
        beta: BetaSpec::PlusMinus { k: 10, scale: 2.0 },
        family: Family::Gaussian,
        noise_sd: 1.0,
        standardize: true,
    };
    let prob = spec.generate(&mut Pcg64::new(7));
    println!("problem: n={} p={} family={}", prob.n(), prob.p(), prob.family.name());

    let mut cfg = PathConfig::new(LambdaKind::Bh { q: 0.1 });
    cfg.length = 30;
    let opts = PathOptions::new(cfg);

    // Fit once with the native gradient engine, once through the
    // AOT-compiled XLA artifact; the paths must agree.
    let native_fit = fit_path(&prob, &opts, &NativeGradient(&prob));

    let manifest = Manifest::load(&default_artifact_dir())?;
    let grad = ArtifactGradient::new(&manifest, &prob)?;
    println!(
        "xla engine: bucket {:?}, padding overhead {:.2}x",
        grad.bucket(),
        grad.padding_overhead()
    );
    let xla_fit = fit_path(&prob, &opts, &grad);

    println!("\nstep  sigma     active  screened  |Δβ| native-vs-xla");
    let steps = native_fit.steps.len().min(xla_fit.steps.len());
    for m in 0..steps {
        let a = native_fit.beta_at(m, prob.p_total());
        let b = xla_fit.beta_at(m, prob.p_total());
        let diff = a
            .iter()
            .zip(&b)
            .map(|(x, y)| (x - y).abs())
            .fold(0.0f64, f64::max);
        let s = &native_fit.steps[m];
        println!(
            "{m:>4}  {:<8.4} {:>6}  {:>8}  {:.2e}",
            s.sigma, s.n_active, s.n_screened_rule, diff
        );
        assert!(diff < 1e-6, "engines disagree at step {m}: {diff}");
    }
    println!(
        "\nOK: {} path steps agree across engines (native vs {}), {} violations",
        steps,
        grad.label(),
        native_fit.total_violations
    );
    Ok(())
}

//! END-TO-END driver (EXPERIMENTS.md §End-to-end): the paper's §1
//! motivating workload — K-times-repeated k-fold cross-validation of a
//! full SLOPE regularization path — run through the Layer-3 coordinator
//! on a real small workload (the golub leukemia stand-in, 38 × 7129),
//! with the strong screening rule on and off.
//!
//! This exercises every layer in composition: data → coordinator (worker
//! pool, fold scheduling) → path driver (screening + KKT safeguard) →
//! FISTA → prox, and reports the paper's headline quantity: the
//! wall-clock ratio between screened and unscreened fits.
//!
//! Run: `cargo run --release --example cross_validation -- --folds 5 --repeats 2`

use slope_screen::cli::Args;
use slope_screen::coordinator::{cross_validate, CvConfig};
use slope_screen::data::real::RealDataset;
use slope_screen::slope::lambda::{LambdaKind, PathConfig};
use slope_screen::slope::path::{PathOptions, Strategy};

fn main() {
    let parsed = Args::new("repeated k-fold CV of a SLOPE path on golub (end-to-end driver)")
        .opt("folds", "5", "folds per repeat")
        .opt("repeats", "2", "repeats")
        .opt("threads", "0", "worker threads (0 = auto)")
        .opt("path-length", "100", "path points")
        .opt("q", "0.01", "BH parameter")
        .flag("no-screening-baseline", "skip the unscreened baseline")
        .parse();

    let prob = RealDataset::Golub.load();
    println!(
        "workload: golub stand-in, n={} p={} family={}; {}x{}-fold CV over a {}-step path",
        prob.n(),
        prob.p(),
        prob.family.name(),
        parsed.usize("repeats"),
        parsed.usize("folds"),
        parsed.usize("path-length"),
    );

    let mut cfg = PathConfig::new(LambdaKind::Bh { q: parsed.f64("q") });
    cfg.length = parsed.usize("path-length");
    let cv_cfg = CvConfig {
        folds: parsed.usize("folds"),
        repeats: parsed.usize("repeats"),
        threads: parsed.usize("threads"),
        seed: 2020,
    };

    let mut times = Vec::new();
    let strategies: Vec<Strategy> = if parsed.bool("no-screening-baseline") {
        vec![Strategy::StrongSet]
    } else {
        vec![Strategy::StrongSet, Strategy::NoScreening]
    };
    for strategy in strategies {
        let opts = PathOptions::new(cfg.clone()).with_strategy(strategy);
        let res = cross_validate(&prob, &opts, &cv_cfg);
        let total_viol: usize = res.folds.iter().map(|f| f.violations).sum();
        let mean_fit: f64 = slope_screen::linalg::ops::mean(
            &res.folds.iter().map(|f| f.fit_time).collect::<Vec<_>>(),
        );
        println!(
            "\nstrategy={:<8}  wall={:.3}s  ({} fits, mean fit {:.3}s, violations {})",
            strategy.name(),
            res.wall_time,
            res.folds.len(),
            mean_fit,
            total_viol
        );
        println!(
            "  model selection: best sigma index {} of {}, held-out deviance {:.4} ± {:.4}",
            res.best_index,
            res.sigmas.len(),
            res.mean_deviance[res.best_index],
            res.se_deviance[res.best_index]
        );
        times.push((strategy.name(), res.wall_time));
    }
    if times.len() == 2 {
        println!(
            "\nscreening speed-up on this workload: {:.1}x (no-screening {:.2}s / strong {:.2}s)",
            times[1].1 / times[0].1,
            times[1].1,
            times[0].1
        );
    }
}

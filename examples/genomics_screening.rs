//! Genomics workload (the paper's motivating p ≫ n setting): sorted-ℓ1
//! penalized logistic regression on the golub leukemia stand-in
//! (38 × 7129 microarray), with and without the strong screening rule.
//!
//! This is the Table 3 "golub/logistic" row in miniature: screening turns
//! a full-width path into a sequence of tiny reduced problems.
//!
//! Run: `cargo run --release --example genomics_screening`

use std::time::Instant;

use slope_screen::data::real::RealDataset;
use slope_screen::slope::lambda::{LambdaKind, PathConfig};
use slope_screen::slope::path::{fit_path, NativeGradient, PathOptions, Strategy};

fn main() {
    let prob = RealDataset::Golub.load();
    println!(
        "golub stand-in: n={} p={} family={} ({} positive labels)",
        prob.n(),
        prob.p(),
        prob.family.name(),
        prob.y.iter().filter(|&&v| v == 1.0).count()
    );

    let mut cfg = PathConfig::new(LambdaKind::Bh { q: 0.01 });
    cfg.length = 100;

    for strategy in [Strategy::StrongSet, Strategy::NoScreening] {
        let opts = PathOptions::new(cfg.clone()).with_strategy(strategy);
        let t = Instant::now();
        let fit = fit_path(&prob, &opts, &NativeGradient(&prob));
        let wall = t.elapsed().as_secs_f64();
        let max_active = fit.steps.iter().map(|s| s.n_active).max().unwrap_or(0);
        let mean_screened: f64 = slope_screen::linalg::ops::mean(
            &fit.steps.iter().skip(1).map(|s| s.n_screened_rule as f64).collect::<Vec<_>>(),
        );
        println!(
            "\nstrategy={:<8}  {} steps in {:.3}s{}",
            strategy.name(),
            fit.steps.len(),
            wall,
            fit.stopped_early.map(|r| format!("  (stopped: {r})")).unwrap_or_default()
        );
        println!(
            "  max active predictors: {max_active} / {}  (mean screened set: {mean_screened:.1})",
            prob.p()
        );
        println!("  violations: {}", fit.total_violations);
        let (ts, tv, tk) = slope_screen::slope::path::phase_totals(&fit);
        println!("  phase seconds: screen={ts:.4} solve={tv:.4} kkt={tk:.4}");
    }
}

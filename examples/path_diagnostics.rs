//! Path diagnostics (Figure 1 in miniature): screened-set vs active-set
//! size along the path, comparing the strong rule against the gap-safe
//! baseline, across correlation levels.
//!
//! Run: `cargo run --release --example path_diagnostics -- --scale 0.5`

use slope_screen::cli::Args;
use slope_screen::data::synth::{BetaSpec, DesignKind, SyntheticSpec};
use slope_screen::rng::Pcg64;
use slope_screen::slope::family::Family;
use slope_screen::slope::lambda::{LambdaKind, PathConfig};
use slope_screen::slope::path::{fit_path, NativeGradient, PathOptions};

fn main() {
    let parsed = Args::new("screened vs active set along the path (Fig. 1 in miniature)")
        .opt("scale", "0.2", "problem scale relative to the paper's n=200, p=5000")
        .opt("rhos", "0.0,0.4,0.8", "correlation levels")
        .parse();
    let scale = parsed.f64("scale");
    let n = (200.0 * scale).max(20.0) as usize;
    let p = (5000.0 * scale).max(50.0) as usize;

    for rho in parsed.f64_list("rhos") {
        let spec = SyntheticSpec {
            n,
            p,
            rho,
            design: DesignKind::Compound,
            beta: BetaSpec::Normal { k: p / 4 },
            family: Family::Gaussian,
            noise_sd: 1.0,
            standardize: true,
        };
        let prob = spec.generate(&mut Pcg64::new(11));
        let mut cfg = PathConfig::new(LambdaKind::Bh { q: 0.005 });
        cfg.length = 50;
        let mut opts = PathOptions::new(cfg);
        opts.record_safe = true;
        let fit = fit_path(&prob, &opts, &NativeGradient(&prob));
        println!("\nrho = {rho}  (n={n}, p={p}, k=p/4; {} steps)", fit.steps.len());
        println!("step  sigma      active  strong  safe");
        for (i, s) in fit.steps.iter().enumerate() {
            if i % 5 != 0 && i + 1 != fit.steps.len() {
                continue;
            }
            println!(
                "{i:>4}  {:<9.4} {:>6}  {:>6}  {:>5}",
                s.sigma,
                s.n_active,
                s.n_screened_rule,
                s.n_safe.map(|v| v.to_string()).unwrap_or_default()
            );
        }
        println!("violations: {}", fit.total_violations);
    }
}

//! End-to-end serving demo: start the SLOPE fit server on a Unix socket,
//! then drive it through a client exactly as an external process would —
//! cold path fit, cached repeat, warm-started refinement, a `fit_point`
//! stream that reuses the previous point's screened state, predictions,
//! and a stats snapshot.
//!
//! Run: `cargo run --release --example serving`

#[cfg(not(unix))]
fn main() {
    eprintln!("the serving demo drives the unix-socket transport; unavailable on this platform");
}

#[cfg(unix)]
fn main() {
    use std::sync::Arc;
    use std::time::Instant;

    use slope_screen::jsonio::Json;
    use slope_screen::serve::client::connect_with_retry;
    use slope_screen::serve::protocol::{request_line, synth_dataset_json};
    use slope_screen::serve::{Server, ServerConfig};

    let sock = std::env::temp_dir().join(format!("slope-serving-demo-{}.sock", std::process::id()));
    let server = Arc::new(Server::new(ServerConfig { threads: 0, queue: 16, cache: true, fit_threads: 0, ..Default::default() }));
    let server_thread = {
        let server = Arc::clone(&server);
        let sock = sock.clone();
        std::thread::spawn(move || server.serve_unix(&sock))
    };

    let mut client = connect_with_retry(&sock, 100, 10).expect("server socket");
    let dataset = || synth_dataset_json(200, 2000, 20, 0.3, "gaussian", 2020);
    let mut id = 0u64;
    let mut send = |client: &mut slope_screen::serve::client::Client,
                    op: &str,
                    fields: Vec<(&str, Json)>| {
        id += 1;
        let line = request_line(id, op, fields);
        let t0 = Instant::now();
        let resp = client.round_trip(&line).expect("round trip");
        let elapsed = t0.elapsed().as_secs_f64();
        let json = Json::parse(&resp).expect("response JSON");
        assert_eq!(json.field("ok"), Some(&Json::Bool(true)), "request failed: {resp}");
        (json.field("result").unwrap().clone(), elapsed)
    };

    println!("== fit_path: cold fit vs cache hit vs warm sibling fit ==");
    let (cold, t_cold) = send(
        &mut client,
        "fit_path",
        vec![("dataset", dataset()), ("q", Json::Num(0.02)), ("path_length", Json::Num(40.0))],
    );
    println!(
        "cold   : {:>8.1}ms  source={:<9} strategy={:<8} steps={}",
        t_cold * 1e3,
        cold.field("source").unwrap().as_str().unwrap(),
        cold.field("strategy").unwrap().as_str().unwrap(),
        cold.field("steps").unwrap().as_usize().unwrap(),
    );
    let (hit, t_hit) = send(
        &mut client,
        "fit_path",
        vec![("dataset", dataset()), ("q", Json::Num(0.02)), ("path_length", Json::Num(40.0))],
    );
    println!(
        "repeat : {:>8.1}ms  source={:<9} ({}x faster than the cold fit)",
        t_hit * 1e3,
        hit.field("source").unwrap().as_str().unwrap(),
        (t_cold / t_hit.max(1e-9)).round(),
    );
    let (warm, t_warm) = send(
        &mut client,
        "fit_path",
        vec![("dataset", dataset()), ("q", Json::Num(0.02)), ("path_length", Json::Num(60.0))],
    );
    println!(
        "refine : {:>8.1}ms  source={:<9} strategy={:<8} (longer path, warm-started)",
        t_warm * 1e3,
        warm.field("source").unwrap().as_str().unwrap(),
        warm.field("strategy").unwrap().as_str().unwrap(),
    );

    println!("\n== fit_point stream: previous-set screening across requests ==");
    for (i, ratio) in [0.5, 0.45, 0.4, 0.35, 0.3].iter().enumerate() {
        let (point, t) = send(
            &mut client,
            "fit_point",
            vec![
                ("dataset", dataset()),
                ("q", Json::Num(0.02)),
                ("sigma_ratio", Json::Num(*ratio)),
            ],
        );
        println!(
            "point {} : sigma_ratio={:.2}  {:>7.1}ms  warm={:<5} strategy={:<8} active={:<4} fitted={:<5} iters={}",
            i,
            ratio,
            t * 1e3,
            point.field("warm").unwrap().to_string(),
            point.field("strategy").unwrap().as_str().unwrap(),
            point.field("n_active").unwrap().as_usize().unwrap(),
            point.field("n_fitted").unwrap().as_usize().unwrap(),
            point.field("solver_iterations").unwrap().as_usize().unwrap(),
        );
    }

    println!("\n== predict on fresh rows ==");
    let rows: Vec<Json> = (0..3)
        .map(|i| {
            Json::nums(&(0..2000).map(|j| (((i * 37 + j * 13) % 11) as f64 - 5.0) * 0.05).collect::<Vec<f64>>())
        })
        .collect();
    let (pred, t_pred) = send(
        &mut client,
        "predict",
        vec![
            ("dataset", dataset()),
            ("q", Json::Num(0.02)),
            ("path_length", Json::Num(40.0)),
            ("x", Json::Arr(rows)),
        ],
    );
    println!(
        "scored {} rows in {:.1}ms at step {} (model from cache: {})",
        pred.field("eta").unwrap().items().len(),
        t_pred * 1e3,
        pred.field("step").unwrap().as_usize().unwrap(),
        pred.field("source").unwrap().as_str().unwrap() == "cache",
    );

    println!("\n== stats ==");
    let (stats, _) = send(&mut client, "stats", vec![]);
    println!("{}", stats.to_string());

    let (_, _) = send(&mut client, "shutdown", vec![]);
    drop(client);
    server_thread.join().expect("server thread").expect("server exit");
    println!("\nserver shut down cleanly");
}
